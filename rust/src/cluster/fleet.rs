//! The fleet loop: N replica simulators on one shared virtual clock behind
//! a session router.
//!
//! A fleet run is a deterministic merge of up to five event sources:
//!
//! 1. **Fleet arrivals** — the scenario's arrival plan, plus arrivals the
//!    run itself creates: closed-loop agents chain their next session
//!    `think_time` after the previous completes, workflow dependents are
//!    released when their fleet-wide join barrier resolves, and sessions
//!    lost to a replica crash re-enter as re-routed continuations. Each
//!    arrival is routed *at its timestamp* against the replicas' live load
//!    surfaces and injected into the chosen [`SimDriver`].
//! 2. **Replica events** — each replica advances one event at a time; the
//!    loop always processes the globally earliest thing (arrivals win
//!    exact-timestamp ties, mirroring the simulator's low sequence band
//!    for injected arrivals; replica ties resolve by index).
//! 3. **Completions** — burst/session completions drain back to the fleet
//!    after every step, resolving workflow gates *fleet-wide*: a join's
//!    workers may live on different replicas than the supervisor they
//!    release ([`SimDriver::open_step_gate`]).
//! 4. **Chaos events** — scripted and seeded replica faults
//!    ([`crate::config::ChaosConfig`]): a crash retires the replica
//!    mid-flight (its KV state and queue are gone), harvests every
//!    unfinished session into a *continuation script* that re-prefills the
//!    lost context cold, and re-routes each at its own resume instant; a
//!    drain stops routing to the replica but lets it finish its queue; a
//!    restart boots a cold replacement after the model-load latency. Chaos
//!    events win exact-time ties against arrivals and replica events, so a
//!    same-microsecond arrival is routed *around* the dying replica.
//! 5. **Control ticks** — the autoscaler ([`super::Autoscaler`],
//!    [`crate::config::AutoscaleConfig`]) ticks every `interval_us` of
//!    virtual time, reads the serving replicas' mean
//!    [`crate::engine::ReplicaLoad::pressure`], and may boot a replica
//!    (cold start via [`SimDriver::new_fast_boot_at`]: model-load latency,
//!    empty radix cache) or drain one (it finishes its placed work, then
//!    leaves the GPU-time accounting — no tokens are lost). At equal
//!    timestamps the tie order is chaos > arrival > control tick > replica
//!    event: faults preempt everything, a same-microsecond arrival is
//!    routed on the pre-tick fleet, and a scale order lands before the
//!    replicas' own events at that instant. The seeded chaos crash process
//!    covers only the initial `n_replicas` — autoscale-booted replicas can
//!    drain but never crash (scripted events are validated against the
//!    initial fleet, and the per-replica crash streams are drawn at start).
//!
//! With one replica and an open-loop scenario this machinery collapses to
//! exactly the batch event order, so `run_cluster(.., 1, ..)` reproduces
//! [`crate::engine::run_scenario`] byte-for-byte under every router — the
//! lock that keeps the `SimDriver` refactor a pure refactor
//! (`rust/tests/cluster.rs`). Closed-loop and workflow scenarios re-route
//! fleet-created arrivals at their own timestamps, which can order
//! differently from the batch path only when such an arrival collides with
//! an internal event on the exact microsecond (see
//! `docs/ARCHITECTURE.md` § Fleet layer). With no chaos configured the
//! fault machinery is skipped entirely and outputs stay byte-identical to
//! the pre-chaos fleet; with no (or an inert, or a never-triggering)
//! autoscale config the control plane likewise leaves every byte of the
//! static-fleet output unchanged (`rust/tests/properties.rs`).

use super::autoscale::{Autoscaler, ScaleDecision, SizeTracker};
use super::router::Router;
use crate::config::{Config, FaultKind, RouterPolicy, CHAOS_STREAM};
use crate::engine::sim::task_critical_paths_ms;
use crate::engine::{
    CrashResume, DriverEvent, ExecEvent, ExecEventKind, ExecTrace, Policy, SimDriver, SimOutcome,
};
use crate::gpusim::CostModel;
use crate::host::{HostReport, HostSamples};
use crate::metrics::{
    load_cov, percentile, AutoscaleStats, ChaosStats, FleetReport, SloReport, Summary,
    WorkflowReport,
};
use crate::obs::{InstantEvent, InstantKind, ObsLog, PhaseReport, ProbeLog, ProbeSample};
use crate::util::rng::Rng;
use crate::workflow::WorkflowPlan;
use crate::workload::{Scenario, SessionScript};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

/// Results of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub policy_name: String,
    pub router: RouterPolicy,
    pub replicas: usize,
    /// Fleet-level aggregation (the headline surface).
    pub report: FleetReport,
    /// Each replica's own outcome, in replica order. After a crash this is
    /// the *replacement* replica's outcome; the crashed incarnation's
    /// counters are folded into the fleet report.
    pub per_replica: Vec<SimOutcome>,
    /// Replica index per global session (the final routing record — a
    /// crashed session's entry points at the replica that finished it).
    pub placements: Vec<usize>,
    /// Merged telemetry: every incarnation's spans and instants retagged
    /// to fleet identity (pid = replica, tid = global session), plus the
    /// fleet-global probe grid. `None` when `Config::obs` is inert.
    pub obs: Option<ObsLog>,
    /// Merged execution-event stream (replica-stamped, global session
    /// ids, time-ordered). `None` unless capture was requested via
    /// [`run_cluster_recorded`].
    pub exec: Option<ExecTrace>,
}

/// Fleet-side workflow orchestration: gate counters over the compiled
/// [`WorkflowPlan`], resolved from completions across *all* replicas.
struct WfFleet {
    plan: WorkflowPlan,
    /// Unresolved arrival-gate dependencies per session.
    arr_remaining: Vec<usize>,
    /// Unresolved step-gate dependencies per (session, step).
    step_remaining: Vec<Vec<usize>>,
    /// Unfinished sessions per task.
    task_left: Vec<usize>,
    /// Completion timestamp per task.
    task_done_us: Vec<Option<u64>>,
    /// Ideal critical-path lower bound per task (ms) — same cost model on
    /// every (homogeneous) replica.
    task_cp_ms: Vec<f64>,
}

/// Run a scenario on an `n_replicas`-GPU fleet under `router` (timeline
/// retained per replica, like [`crate::engine::run_scenario`]).
pub fn run_cluster(
    cfg: &Config,
    policy: Policy,
    scenario: &Scenario,
    n_replicas: usize,
    router: RouterPolicy,
    seed: u64,
) -> crate::Result<FleetOutcome> {
    run_cluster_inner(cfg, policy, scenario, n_replicas, router, seed, false, false)
}

/// [`run_cluster`] without per-token timeline retention — the fleet-sweep
/// hot path. Aggregates are byte-identical to [`run_cluster`].
pub fn run_cluster_fast(
    cfg: &Config,
    policy: Policy,
    scenario: &Scenario,
    n_replicas: usize,
    router: RouterPolicy,
    seed: u64,
) -> crate::Result<FleetOutcome> {
    run_cluster_inner(cfg, policy, scenario, n_replicas, router, seed, true, false)
}

/// [`run_cluster`] with execution-event capture: every replica incarnation
/// records its stream, the fleet stamps each event with its replica id and
/// global session id, and the streams merge time-ordered (ties: replica
/// order) into the returned [`ExecTrace`] — the fleet counterpart of
/// [`crate::engine::run_scenario_recorded`].
pub fn run_cluster_recorded(
    cfg: &Config,
    policy: Policy,
    scenario: &Scenario,
    n_replicas: usize,
    router: RouterPolicy,
    seed: u64,
) -> crate::Result<(FleetOutcome, ExecTrace)> {
    let mut out = run_cluster_inner(cfg, policy, scenario, n_replicas, router, seed, false, true)?;
    let trace = out.exec.take().expect("capture was requested");
    Ok((out, trace))
}

/// The affinity-unit key of one global session: closed-loop agent slot, or
/// owning workflow task. Independent open-loop sessions have none.
fn unit_key(g: usize, chain: Option<(usize, u64)>, wf: Option<&WfFleet>) -> Option<u64> {
    if let Some((stride, _)) = chain {
        return Some((g % stride) as u64);
    }
    wf.map(|w| w.plan.task_of[g] as u64)
}

/// The remainder of a session whose replica crashed after `bursts_done`
/// fully emitted decode bursts: everything already produced (prompt,
/// emitted bursts, consumed tool outputs — including the in-flight burst's
/// resume tokens) folds into one cold re-prefill, because the KV state
/// died with the replica and must be recomputed; decoding restarts at the
/// in-flight burst. The template-shared system prompt stays shared (the
/// new replica's radix cache can still serve it); everything beyond is
/// marked session-unique so recomputed context is never counted as
/// cross-session reuse.
fn continuation_script(orig: &SessionScript, bursts_done: usize) -> SessionScript {
    let k = bursts_done;
    if k == 0 {
        return orig.clone();
    }
    let shared = (orig.cold_prefill_tokens - orig.unique_prompt_tokens) as u64;
    let mut cold = orig.cold_prefill_tokens as u64 + orig.first_decode_tokens as u64;
    for s in &orig.steps[..k - 1] {
        cold += s.resume_tokens as u64 + s.decode_tokens as u64;
    }
    cold += orig.steps[k - 1].resume_tokens as u64;
    SessionScript {
        id: orig.id,
        kind: orig.kind,
        cold_prefill_tokens: cold as u32,
        template: orig.template,
        unique_prompt_tokens: (cold - shared) as u32,
        first_decode_tokens: orig.steps[k - 1].decode_tokens,
        steps: orig.steps[k..].to_vec(),
    }
}

/// Replica availability under the chaos layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RepState {
    Up,
    /// Routed around but still finishing its queue; only a scripted
    /// Restore revives it.
    Draining,
    /// Crashed; a cold replacement boots at `until`.
    Down { until: u64 },
}

/// Deterministic fault-event source: scripted events (sorted, file order
/// on ties), an optional per-replica seeded crash process, and the
/// auto-restore timers crashes schedule. At equal timestamps restores fire
/// before scripted faults before seeded crashes — a restore and a crash on
/// one microsecond leave the replica down, never ambiguous.
struct ChaosState {
    scripted: Vec<crate::config::FaultEvent>,
    next_scripted: usize,
    /// Next seeded crash instant per replica; None while down/draining.
    seeded_at: Vec<Option<u64>>,
    rngs: Vec<Rng>,
    mtbf_us: u64,
    restart_us: u64,
    /// Auto-restore timers: (boot instant, replica).
    restores: BinaryHeap<Reverse<(u64, usize)>>,
    states: Vec<RepState>,
    stats: ChaosStats,
}

/// (source band, replica): restores = 0, scripted = 1, seeded = 2.
type ChaosPick = (u64, u8, usize, FaultKind);

impl ChaosState {
    fn new(cfg: &crate::config::ChaosConfig, n_replicas: usize, seed: u64) -> crate::Result<Self> {
        for ev in &cfg.events {
            anyhow::ensure!(
                ev.replica < n_replicas,
                "chaos event targets replica {} but the fleet has {n_replicas}",
                ev.replica
            );
        }
        let mut scripted = cfg.events.clone();
        scripted.sort_by_key(|e| e.at_us); // stable: ties keep file order
        let mut state = Self {
            scripted,
            next_scripted: 0,
            seeded_at: vec![None; n_replicas],
            rngs: (0..n_replicas)
                .map(|r| Rng::fold(Rng::fold(seed, CHAOS_STREAM), r as u64))
                .collect(),
            mtbf_us: cfg.mtbf_us,
            restart_us: cfg.restart_us,
            restores: BinaryHeap::new(),
            states: vec![RepState::Up; n_replicas],
            stats: ChaosStats::default(),
        };
        for r in 0..n_replicas {
            state.draw_seeded(r, 0);
        }
        Ok(state)
    }

    /// Arm the next seeded crash for an Up replica (exponential inter-fault
    /// gap from the replica's own stream; ≥ 1 us so it never aliases the
    /// arming instant).
    fn draw_seeded(&mut self, r: usize, now_us: u64) {
        if self.mtbf_us == 0 {
            return;
        }
        let u = self.rngs[r].f64();
        let gap = (-(1.0 - u).ln() * self.mtbf_us as f64).max(1.0) as u64;
        self.seeded_at[r] = Some(now_us + gap);
    }

    /// The earliest pending fault, if any (not consumed).
    fn peek(&self) -> Option<ChaosPick> {
        let mut best: Option<ChaosPick> = None;
        if let Some(&Reverse((t, r))) = self.restores.peek() {
            best = Some((t, 0, r, FaultKind::Restore));
        }
        if let Some(ev) = self.scripted.get(self.next_scripted) {
            let c = (ev.at_us, 1u8, ev.replica, ev.kind);
            if best.is_none_or(|b| (c.0, c.1) < (b.0, b.1)) {
                best = Some(c);
            }
        }
        if let Some((t, r)) = self
            .seeded_at
            .iter()
            .enumerate()
            .filter_map(|(r, t)| t.map(|t| (t, r)))
            .min()
        {
            let c = (t, 2u8, r, FaultKind::Crash);
            if best.is_none_or(|b| (c.0, c.1) < (b.0, b.1)) {
                best = Some(c);
            }
        }
        best
    }

    /// Consume the event returned by [`ChaosState::peek`].
    fn pop(&mut self, pick: ChaosPick) {
        match pick.1 {
            0 => {
                self.restores.pop();
            }
            1 => self.next_scripted += 1,
            _ => self.seeded_at[pick.2] = None,
        }
    }

    /// Earliest instant any replica returns to Up (for arrivals that find
    /// no eligible replica): the next auto-restore or scripted Restore.
    fn earliest_revival(&self) -> Option<u64> {
        let auto = self.restores.peek().map(|&Reverse((t, _))| t);
        let scripted = self.scripted[self.next_scripted..]
            .iter()
            .find(|e| e.kind == FaultKind::Restore)
            .map(|e| e.at_us);
        match (auto, scripted) {
            (Some(a), Some(s)) => Some(a.min(s)),
            (a, s) => a.or(s),
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_cluster_inner(
    cfg: &Config,
    policy: Policy,
    scenario: &Scenario,
    n_replicas: usize,
    router_policy: RouterPolicy,
    seed: u64,
    fast: bool,
    record_exec: bool,
) -> crate::Result<FleetOutcome> {
    anyhow::ensure!(n_replicas >= 1, "a fleet needs at least one replica");
    scenario.validate()?;
    let cfg = scenario.effective_config(cfg);
    // Observability gates. All three are false on the inert default, and
    // every obs code path below is behind one of them, so legacy outputs
    // stay byte-identical (the same contract as chaos and autoscale).
    let obs_active = cfg.obs.is_active();
    let trace_on = obs_active && cfg.obs.trace;
    let probe_on = obs_active && cfg.obs.probe.is_active();
    // Fleet-side telemetry: control-plane instants (chaos faults, scale
    // decisions), the fleet-global probe grid, and harvested exec streams.
    let mut fleet_instants: Vec<InstantEvent> = Vec::new();
    let mut fleet_probes: Vec<ProbeSample> = Vec::new();
    let mut next_probe_us: u64 = cfg.obs.probe.interval_us;
    let mut exec_acc: Vec<ExecEvent> = Vec::new();
    let mut fleet_exec: Vec<ExecEvent> = Vec::new();
    let chaos_active = scenario.chaos.as_ref().is_some_and(|c| c.is_active());
    let mut chaos = match &scenario.chaos {
        Some(c) if c.is_active() => Some(ChaosState::new(c, n_replicas, seed)?),
        _ => None,
    };
    // The control plane. `n_replicas` is the *initial* fleet size and must
    // sit inside the autoscale band; an inert config leaves `scaler` None
    // and every code path below identical to the static fleet.
    let mut scaler = match &scenario.autoscale {
        Some(a) if a.is_active() => {
            a.validate()?;
            anyhow::ensure!(
                a.min_replicas <= n_replicas && n_replicas <= a.max_replicas,
                "autoscale: initial fleet size {n_replicas} is outside the \
                 [{}, {}] replica band",
                a.min_replicas,
                a.max_replicas
            );
            Some(Autoscaler::new(a.clone()))
        }
        _ => None,
    };
    let as_present = scaler.is_some();
    // Max *stepped* timestamp is the wall clock whenever replicas can boot
    // after the last real event (chaos restarts, autoscale cold boots): an
    // idle late boot must not stretch the horizon. On a static fault-free
    // fleet it equals the legacy max-over-`now_us`.
    let track_wall = chaos_active || as_present;

    // -- 1) lower the scenario into scripts + the fleet arrival plan --------
    // `chain` = closed-loop chaining (stride, think time); `wf` = fleet-wide
    // workflow gates. `seeds` are the unconditional (wave-0 / root /
    // open-loop) arrivals in session-index order.
    let mut chain: Option<(usize, u64)> = None;
    let mut wf: Option<WfFleet> = None;
    let tool_faults = scenario
        .workflow
        .as_ref()
        .is_some_and(|w| w.effective_spec().has_tool_faults());
    let (scripts, seeds): (Vec<SessionScript>, Vec<(usize, u64)>) = if scenario.workflow.is_some()
    {
        let cw = crate::workflow::compile(scenario, cfg.model.kind, seed);
        let cost = CostModel::new(&cfg.model, &cfg.gpu);
        let seeds = cw.plan.root_arrivals();
        // Same gate initialization as the in-simulator WfState — both sides
        // call the shared WorkflowPlan helpers, so semantics cannot drift.
        wf = Some(WfFleet {
            arr_remaining: cw.plan.initial_arrival_gates(),
            step_remaining: cw.plan.initial_step_gates(),
            task_left: cw.plan.task_session_counts(),
            task_done_us: vec![None; cw.plan.n_tasks],
            task_cp_ms: task_critical_paths_ms(&cost, &cw.scripts, &cw.plan),
            plan: cw.plan,
        });
        (cw.scripts, seeds)
    } else {
        let wl = scenario.instantiate(cfg.model.kind, seed);
        let (scripts, arrivals): (Vec<_>, Vec<_>) = wl
            .trace
            .events
            .into_iter()
            .map(|e| (e.script, e.arrival_us))
            .unzip();
        let seeds = match scenario.closed_loop() {
            Some((stagger_us, think_time_us)) => {
                // Wave 0 staggered across the agent slots; waves > 0 chain
                // at fleet level (each re-routed at its arrival timestamp).
                let slots = scenario.n_agents.max(1);
                chain = Some((slots, think_time_us));
                (0..slots.min(scripts.len()))
                    .map(|a| (a, a as u64 * stagger_us))
                    .collect()
            }
            None => arrivals.iter().copied().enumerate().collect(),
        };
        (scripts, seeds)
    };
    let mut scripts = scripts;
    let total = scripts.len();

    // -- 2) replicas, router, fleet arrival queue ---------------------------
    let mut drivers: Vec<SimDriver> = (0..n_replicas)
        .map(|_| {
            if fast {
                SimDriver::new_fast(&cfg, policy)
            } else {
                SimDriver::new(&cfg, policy)
            }
        })
        .collect();
    // Per-replica host queues: each replica slot folds its own stream off
    // the run seed (HOST_STREAM), so adding replicas never perturbs the
    // draws of existing ones. No-op when `cfg.host` is inert.
    for (r, d) in drivers.iter_mut().enumerate() {
        d.set_host_seed(seed, r as u64);
    }
    if record_exec {
        for d in drivers.iter_mut() {
            d.record_events();
        }
    }
    let mut router = Router::new(router_policy);
    // (time, fleet-seq, global session): seq makes equal-time arrivals pop
    // in creation order — seed order first, then fleet-created arrivals.
    let mut queue: BinaryHeap<Reverse<(u64, u64, usize)>> = BinaryHeap::new();
    let mut fseq: u64 = 0;
    for &(g, t) in &seeds {
        queue.push(Reverse((t, fseq, g)));
        fseq += 1;
    }

    let mut placements = vec![usize::MAX; total];
    let mut local_of = vec![usize::MAX; total];
    let mut local2global: Vec<Vec<usize>> = vec![Vec::new(); n_replicas];
    let mut injected = 0usize;
    let mut finished = vec![false; n_replicas];
    let mut events: Vec<DriverEvent> = Vec::new();
    // Prompt ids are only materialized when the cache-aware router can use
    // them (radix cache live on the paged path with sharing on). Same-
    // template prompts are one deterministic stream — a shorter prompt is a
    // prefix of a longer one — so the longest materialized vector per
    // template is cached and sliced instead of regenerated per arrival
    // (sessions with per-task unique suffixes bypass the cache; so do
    // post-crash continuations, whose context is session-unique).
    let want_prompt =
        router_policy == RouterPolicy::CacheAware && cfg.kv.is_paged() && cfg.kv.prefix_sharing;
    let mut prompt_cache: BTreeMap<u32, Vec<u32>> = BTreeMap::new();

    // -- chaos bookkeeping --------------------------------------------------
    // `up_mask` stays all-true with chaos off, making every route() call
    // bit-for-bit the legacy decision.
    let mut up_mask = vec![true; n_replicas];
    // Bursts completed in earlier incarnations per session: local burst /
    // gate index c on the current replica is global index c + off[g].
    let mut off = vec![0usize; total];
    // Sessions that crashed while parked on a closed join gate: g → the
    // scripted tool latency to pay once the gate resolves.
    let mut deferred: BTreeMap<usize, u64> = BTreeMap::new();
    // Retired (crashed) replica outcomes, by replica index.
    let mut retired: Vec<(usize, SimOutcome)> = Vec::new();
    // Host-queue samples harvested from crashed incarnations; live replicas
    // contribute theirs at the final gather. Empty when `cfg.host` is inert.
    let mut host_acc = HostSamples::default();
    // Samples harvested from crashed replicas, in per-session order.
    let mut harv_ttfts: Vec<Vec<f64>> = vec![Vec::new(); total];
    let mut harv_tpots: Vec<Vec<f64>> = vec![Vec::new(); total];
    let mut harv_stalls: Vec<Vec<f64>> = vec![Vec::new(); total];
    let mut session_done = vec![false; total];
    let mut done_global = 0usize;
    // Chaos-mode wall clock: the max timestamp actually *stepped* (a cold
    // replacement that boots after the last completion and never runs must
    // not stretch the horizon the way the legacy max-over-now_us would).
    let mut wall_chaos: u64 = 0;
    let mut winding_down = false;

    // -- autoscale bookkeeping ---------------------------------------------
    // All three vecs grow when the controller boots a replica; with no
    // controller they stay at their initial values and cost nothing.
    // `serving[r]`: replica is part of the accounted fleet (false once the
    // controller drains it — chaos restores must not revive it).
    let mut serving = vec![true; n_replicas];
    // Boot instant per replica: 0 for the initial fleet, `tick + boot_us`
    // for controller-booted ones (ineligible for routing before then).
    let mut boot_at = vec![0u64; n_replicas];
    // Replica ordered down but still finishing placed work; it leaves the
    // GPU-time accounting when the loop observes it idle.
    let mut drain_pending = vec![false; n_replicas];
    // GPU-time integral (replica-µs) + time-at-size histogram.
    let mut tracker = SizeTracker::new(n_replicas);
    // Scale events actually committed by the fleet (a Down order can find
    // no drainable victim when chaos holds every serving replica down —
    // the report counts what happened, not what was ordered).
    let (mut as_ups, mut as_downs) = (0u64, 0u64);

    // -- 3) the lockstep merge loop ----------------------------------------
    loop {
        let t_arr = queue.peek().map(|Reverse((t, _, _))| *t);
        let mut t_rep: Option<(u64, usize)> = None;
        for (r, d) in drivers.iter().enumerate() {
            if finished[r] {
                continue;
            }
            if let Some(t) = d.next_event_us() {
                if t_rep.is_none_or(|(bt, _)| t < bt) {
                    t_rep = Some((t, r));
                }
            }
        }
        // Fleet-global probe grid: one row per *serving* replica per grid
        // point, fired strictly before any event source at-or-after that
        // instant — the same pre-event discipline the batch sampler uses
        // (a probe colliding with a crash samples the pre-crash state).
        // The grid never enters any heap; it is drained lazily against the
        // next real event, so with probing off this whole block is one
        // `bool` test per loop iteration.
        if probe_on {
            let t_chaos = chaos
                .as_ref()
                .filter(|_| done_global < total)
                .and_then(|ch| ch.peek().map(|p| p.0));
            let t_tick = scaler
                .as_ref()
                .filter(|_| done_global < total && (t_arr.is_some() || t_rep.is_some()))
                .map(|sc| sc.next_tick_us());
            let next = [t_arr, t_rep.map(|(t, _)| t), t_chaos, t_tick]
                .into_iter()
                .flatten()
                .min();
            if let Some(tn) = next {
                while next_probe_us <= tn {
                    let tp = next_probe_us;
                    let live: Vec<usize> = (0..drivers.len())
                        .filter(|&r| up_mask[r] && serving[r] && boot_at[r] <= tp)
                        .collect();
                    let n_serving = live.len() as u32;
                    for r in live {
                        fleet_probes.push(drivers[r].probe_row(tp, r as u32, n_serving));
                    }
                    next_probe_us += cfg.obs.probe.interval_us;
                }
            }
        }
        // Chaos events win exact-time ties against both other sources: a
        // crash at t kills the replica before a t-stamped arrival routes
        // (it must avoid the dying replica) and before the replica's own
        // t-stamped events run (they die with it). The `t_chaos <= t_rep`
        // gate also guarantees every replica has fully processed its
        // events *before* the fault instant, which is what lets
        // `crash_manifest` treat exactly-at-t arrivals as not yet started.
        // Once every session is done the remaining fault stream is moot.
        if let Some(ch) = chaos.as_mut() {
            if done_global < total {
                if let Some(pick) = ch.peek() {
                    let (t_c, _, r, kind) = pick;
                    let beats_arr = t_arr.is_none_or(|ta| t_c <= ta);
                    let beats_rep = t_rep.is_none_or(|(tr, _)| t_c <= tr);
                    if beats_arr && beats_rep {
                        ch.pop(pick);
                        match kind {
                            FaultKind::Crash => {
                                if !matches!(ch.states[r], RepState::Down { .. }) {
                                    // -- retire the replica mid-flight ----
                                    let t_up = t_c + ch.restart_us;
                                    ch.states[r] = RepState::Down { until: t_up };
                                    up_mask[r] = false;
                                    ch.seeded_at[r] = None;
                                    ch.restores.push(Reverse((t_up, r)));
                                    ch.stats.crashes += 1;
                                    ch.stats.downtime_ms += ch.restart_us as f64 / 1000.0;
                                    if trace_on {
                                        fleet_instants.push(InstantEvent {
                                            t_us: t_c,
                                            replica: r as u32,
                                            kind: InstantKind::Chaos { what: "crash".into() },
                                        });
                                    }
                                    // The session map dies with the
                                    // incarnation: take it so the harvested
                                    // telemetry below can be retagged to
                                    // fleet identity before the replacement
                                    // starts its own (empty) map.
                                    let l2g = std::mem::take(&mut local2global[r]);
                                    let mut old = std::mem::replace(
                                        &mut drivers[r],
                                        SimDriver::new_fast_boot_at(&cfg, policy, t_up),
                                    );
                                    // The replacement reuses slot r's host
                                    // stream: the queue is a property of the
                                    // replica's CPU, reborn empty with it.
                                    drivers[r].set_host_seed(seed, r as u64);
                                    if record_exec {
                                        drivers[r].record_events();
                                        let mut evs = old.take_exec_events();
                                        for e in &mut evs {
                                            e.retag(r as u32, &l2g);
                                        }
                                        exec_acc.append(&mut evs);
                                    }
                                    finished[r] = false;
                                    // Keep every sample the dead replica
                                    // recorded (finished sessions *and*
                                    // the lost ones' partial requests) —
                                    // `finish()` only keeps aggregates.
                                    for (l, &g) in l2g.iter().enumerate() {
                                        if let Some(s) =
                                            old.recorder().sessions_map().get(&(l as u64))
                                        {
                                            harv_ttfts[g].extend_from_slice(&s.ttfts_ms);
                                            harv_tpots[g].extend_from_slice(&s.tpots_ms);
                                        }
                                    }
                                    for (l, ms) in old.memory_stalls() {
                                        harv_stalls[l2g[l]].push(ms);
                                    }
                                    if let Some(s) = old.host_samples() {
                                        host_acc.merge(&s);
                                    }
                                    for cs in old.crash_manifest() {
                                        let g = l2g[cs.local];
                                        scripts[g] =
                                            continuation_script(&scripts[g], cs.bursts_done);
                                        off[g] += cs.bursts_done;
                                        placements[g] = usize::MAX;
                                        local_of[g] = usize::MAX;
                                        injected -= 1;
                                        ch.stats.rerouted_sessions += 1;
                                        ch.stats.redecoded_tokens +=
                                            cs.emitted_in_burst as u64;
                                        match cs.resume {
                                            CrashResume::Now => {
                                                queue.push(Reverse((t_c, fseq, g)));
                                                fseq += 1;
                                            }
                                            CrashResume::At(t) => {
                                                queue.push(Reverse((t, fseq, g)));
                                                fseq += 1;
                                            }
                                            CrashResume::ParkedGate { latency_us } => {
                                                deferred.insert(g, latency_us);
                                            }
                                        }
                                    }
                                    let mut gone = old.finish();
                                    if let Some(log) = &mut gone.obs {
                                        // The fleet owns the probe grid;
                                        // dead incarnations keep only spans
                                        // and instants, retagged to fleet
                                        // identity while their l2g map is
                                        // still at hand.
                                        log.probes = None;
                                        log.retag(r as u32, &l2g);
                                    }
                                    retired.push((r, gone));
                                }
                            }
                            FaultKind::Drain => {
                                if ch.states[r] == RepState::Up {
                                    ch.states[r] = RepState::Draining;
                                    up_mask[r] = false;
                                    ch.seeded_at[r] = None; // drained ≠ crashed
                                    ch.stats.drains += 1;
                                    if trace_on {
                                        fleet_instants.push(InstantEvent {
                                            t_us: t_c,
                                            replica: r as u32,
                                            kind: InstantKind::Chaos { what: "drain".into() },
                                        });
                                    }
                                }
                            }
                            FaultKind::Restore => {
                                // Auto-restores (band 0) only match the
                                // crash that armed them — an early scripted
                                // Restore + re-crash leaves a stale timer.
                                let revive = if pick.1 == 0 {
                                    matches!(ch.states[r], RepState::Down { until } if until == t_c)
                                } else {
                                    ch.states[r] != RepState::Up
                                };
                                if revive {
                                    ch.states[r] = RepState::Up;
                                    up_mask[r] = true;
                                    ch.draw_seeded(r, t_c);
                                    if trace_on {
                                        fleet_instants.push(InstantEvent {
                                            t_us: t_c,
                                            replica: r as u32,
                                            kind: InstantKind::Chaos {
                                                what: "restore".into(),
                                            },
                                        });
                                    }
                                }
                            }
                        }
                        continue;
                    }
                }
            }
        }
        // Control ticks run strictly between the other sources: they lose
        // timestamp ties to chaos (handled above — chaos `continue`s before
        // this point) and to arrivals (`<` against t_arr: a same-microsecond
        // arrival routes on the pre-tick fleet), but win them against
        // replica events (`<=` against t_rep: a scale order lands before
        // the replicas' own events at that instant). Ticks only interleave
        // with real pending work — once every session is done, or the run
        // has stalled, the controller goes quiet so the loop can terminate.
        if let Some(sc) = scaler.as_mut() {
            if done_global < total && (t_arr.is_some() || t_rep.is_some()) {
                let tt = sc.next_tick_us();
                let beats_arr = t_arr.is_none_or(|ta| tt < ta);
                let beats_rep = t_rep.is_none_or(|(tr, _)| tt <= tr);
                if beats_arr && beats_rep {
                    // Mean pressure over the replicas actually serving:
                    // accounted, booted, and not downed/drained by chaos.
                    // Ordered-but-cold boots count separately (`booting`)
                    // so the controller never stacks decisions on them.
                    let (mut sum, mut n_serve, mut booting) = (0.0, 0usize, 0usize);
                    for r in 0..drivers.len() {
                        if !serving[r] {
                            continue;
                        }
                        if boot_at[r] > tt {
                            booting += 1;
                            continue;
                        }
                        if !up_mask[r] {
                            continue;
                        }
                        sum += drivers[r].load().pressure();
                        n_serve += 1;
                    }
                    let signal = sum / n_serve.max(1) as f64;
                    match sc.tick(tt, signal, tracker.size(), booting) {
                        ScaleDecision::Hold => {}
                        ScaleDecision::Up => {
                            // Cold start: the replica pays boot_us of model
                            // load and joins with an empty radix cache. If
                            // every session is already placed the boot is a
                            // sunk cost (sessions never migrate) — it idles,
                            // terminates immediately, and honestly shows up
                            // in the GPU-time integral.
                            let boot = tt + sc.config().boot_us;
                            let mut d = SimDriver::new_fast_boot_at(&cfg, policy, boot);
                            // Fresh replica slot → fresh host stream; index
                            // = current fleet size, never reused (Down
                            // drains in place, it does not pop).
                            d.set_host_seed(seed, drivers.len() as u64);
                            if record_exec {
                                d.record_events();
                            }
                            if trace_on {
                                fleet_instants.push(InstantEvent {
                                    t_us: tt,
                                    replica: drivers.len() as u32,
                                    kind: InstantKind::Autoscale {
                                        serving: tracker.size() as u32,
                                        target: tracker.size() as u32 + 1,
                                    },
                                });
                            }
                            // A replica booted after the arrival stream is
                            // exhausted can never receive work: close it out
                            // immediately so termination never waits on it.
                            // (all_done() is vacuously true on an empty
                            // driver, so `finished` must stay false while
                            // arrivals can still be routed here.)
                            let terminal = (!chaos_active && injected == total) || winding_down;
                            if terminal {
                                d.set_no_more_arrivals();
                            }
                            finished.push(terminal);
                            drivers.push(d);
                            local2global.push(Vec::new());
                            up_mask.push(true);
                            serving.push(true);
                            boot_at.push(boot);
                            drain_pending.push(false);
                            tracker.set_size(tt, tracker.size() + 1);
                            as_ups += 1;
                        }
                        ScaleDecision::Down => {
                            // Drain the newest serving replica (LIFO keeps
                            // the initial fleet — and its chaos streams —
                            // stable). It finishes everything already
                            // placed, then leaves the accounting below.
                            let victim = (0..drivers.len())
                                .rev()
                                .find(|&r| serving[r] && up_mask[r] && boot_at[r] <= tt);
                            if let Some(r) = victim {
                                serving[r] = false;
                                if trace_on {
                                    fleet_instants.push(InstantEvent {
                                        t_us: tt,
                                        replica: r as u32,
                                        kind: InstantKind::Autoscale {
                                            serving: tracker.size() as u32,
                                            target: tracker.size() as u32 - 1,
                                        },
                                    });
                                }
                                // A replica leaving the fleet also leaves
                                // the chaos process: disarm its seeded
                                // stream and mark it Draining so a pending
                                // restore cannot revive it into service.
                                if let Some(ch) = chaos.as_mut() {
                                    if r < ch.states.len() {
                                        ch.states[r] = RepState::Draining;
                                        ch.seeded_at[r] = None;
                                    }
                                }
                                if drivers[r].all_done() {
                                    tracker.set_size(tt, tracker.size() - 1);
                                } else {
                                    drain_pending[r] = true;
                                }
                                as_downs += 1;
                            }
                        }
                    }
                    continue;
                }
            }
        }
        // Arrivals win exact-time ties: injected arrivals sit in the low
        // sequence band of the replica heap, so the replica would order
        // them first anyway — the fleet must have routed them by then.
        let take_arrival = match (t_arr, t_rep) {
            (None, None) => break,
            (Some(ta), Some((tr, _))) => ta <= tr,
            (Some(_), None) => true,
            (None, Some(_)) => false,
        };
        if take_arrival {
            let Reverse((t, _, g)) = queue.pop().expect("peeked above");
            // Routing eligibility: chaos availability (`up_mask`) and —
            // only when a controller is present — autoscale membership
            // (`serving`) and boot completion. With no controller the mask
            // *is* `up_mask`, bit-for-bit the legacy decision.
            let elig_buf: Vec<bool>;
            let elig: &[bool] = if as_present {
                elig_buf = (0..drivers.len())
                    .map(|r| up_mask[r] && serving[r] && boot_at[r] <= t)
                    .collect();
                &elig_buf
            } else {
                &up_mask
            };
            if (chaos_active || as_present) && !elig.iter().any(|&e| e) {
                // Nothing can serve this arrival yet: hold it until the
                // earliest instant a replica (re)enters service — a chaos
                // restore (chaos wins that tie, so the replica is Up again
                // before this arrival re-pops) or a pending cold boot.
                let revival = chaos.as_ref().and_then(|c| c.earliest_revival());
                let boot = (0..drivers.len())
                    .filter(|&r| serving[r] && up_mask[r] && boot_at[r] > t)
                    .map(|r| boot_at[r])
                    .min();
                let tr = match (revival, boot) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
                let Some(tr) = tr else {
                    anyhow::bail!(
                        "fleet unroutable: every replica is down, draining, or drained \
                         at {t} us with no restore or boot pending"
                    );
                };
                queue.push(Reverse((tr.max(t), fseq, g)));
                fseq += 1;
                continue;
            }
            let unit = unit_key(g, chain, wf.as_ref());
            let unique_buf: Vec<u32>;
            let prompt: Option<&[u32]> = if want_prompt {
                let s = &scripts[g];
                if s.unique_prompt_tokens == 0 {
                    let entry = prompt_cache.entry(s.template).or_default();
                    if entry.len() < s.cold_prefill_tokens as usize {
                        *entry = s.system_prompt_ids();
                    }
                    Some(&entry[..s.cold_prefill_tokens as usize])
                } else {
                    unique_buf = s.system_prompt_ids();
                    Some(&unique_buf)
                }
            } else {
                None
            };
            let r = router.route(unit, prompt, &drivers, elig);
            // Still-closed join gates, translated into the (possibly
            // continuation) script's local step indices; gates before
            // `off[g]` belong to bursts already folded into the cold
            // re-prefill.
            let gated: Vec<usize> = wf
                .as_ref()
                .map(|w| {
                    w.step_remaining[g]
                        .iter()
                        .enumerate()
                        .filter(|&(j, &c)| c > 0 && j >= off[g])
                        .map(|(j, _)| j - off[g])
                        .collect()
                })
                .unwrap_or_default();
            let local = drivers[r].inject(scripts[g].clone(), t, &gated);
            debug_assert_eq!(local, local2global[r].len());
            placements[g] = r;
            local_of[g] = local;
            local2global[r].push(g);
            injected += 1;
            // With chaos on, "all sessions placed" is not final — a crash
            // un-places its sessions — so arrival-count termination only
            // applies to the legacy path; chaos runs wind down on
            // completion count instead (below).
            if !chaos_active && injected == total {
                for (r, d) in drivers.iter_mut().enumerate() {
                    d.set_no_more_arrivals();
                    finished[r] = d.all_done(); // replicas that got nothing
                }
            }
            continue;
        }
        let (_, r) = t_rep.expect("one side is Some");
        if !drivers[r].step() {
            finished[r] = true;
            continue;
        }
        if track_wall {
            wall_chaos = wall_chaos.max(drivers[r].now_us());
        }
        drivers[r].drain_events(&mut events);
        for ev in events.drain(..) {
            match ev {
                DriverEvent::BurstDone { sess, burst, t_us } => {
                    let g = local2global[r][sess];
                    let Some(w) = &mut wf else { continue };
                    // One shared implementation of the decrement/release
                    // semantics (WorkflowPlan::resolve_burst) — the fleet
                    // only differs in *where* releases go: arrivals into
                    // the router queue, step gates onto the holding replica.
                    let resolved = w.plan.resolve_burst(
                        g,
                        burst + off[g],
                        &mut w.arr_remaining,
                        &mut w.step_remaining,
                    );
                    for (s2, delay) in resolved.arrivals {
                        // A positive delay is the dependent's folded tool
                        // edge: it executes on the CPU of the replica whose
                        // completion resolved the gate. Zero-delay releases
                        // are pure join barriers and skip the host.
                        let at = if delay > 0 {
                            drivers[r].host_tool_done_at(t_us, delay)
                        } else {
                            t_us
                        };
                        queue.push(Reverse((at, fseq, s2)));
                        fseq += 1;
                    }
                    for (s2, step) in resolved.steps {
                        // Wake the (possibly parked) session on whichever
                        // replica holds it; a target not yet injected
                        // simply arrives with this gate already open. A
                        // session that *crashed while parked on this gate*
                        // re-enters here instead, paying its tool latency
                        // from the resolution instant (gate semantics).
                        if deferred.contains_key(&s2) && step + 1 == off[s2] {
                            let lat = deferred.remove(&s2).expect("checked");
                            // The crashed-parked session pays its tool
                            // latency on the resolving replica's CPU —
                            // same queue the surviving gate-waits use.
                            let at = drivers[r].host_tool_done_at(t_us, lat);
                            queue.push(Reverse((at, fseq, s2)));
                            fseq += 1;
                        } else if placements[s2] != usize::MAX && step >= off[s2] {
                            drivers[placements[s2]].open_step_gate(
                                local_of[s2],
                                step - off[s2],
                                t_us,
                            );
                        }
                    }
                }
                DriverEvent::SessionDone { sess, t_us } => {
                    let g = local2global[r][sess];
                    session_done[g] = true;
                    done_global += 1;
                    if let Some((stride, think_us)) = chain {
                        let next = g + stride;
                        if next < total {
                            queue.push(Reverse((t_us + think_us, fseq, next)));
                            fseq += 1;
                        }
                    }
                    if let Some(w) = &mut wf {
                        let task = w.plan.task_of[g];
                        w.task_left[task] -= 1;
                        if w.task_left[task] == 0 {
                            w.task_done_us[task] = Some(t_us);
                            if record_exec {
                                // Task completion is a *fleet* fact (the
                                // last session may finish on any replica);
                                // stamp the replica that resolved it.
                                fleet_exec.push(ExecEvent {
                                    t_us,
                                    replica: r as u32,
                                    kind: ExecEventKind::TaskDone { task: task as u64 },
                                });
                            }
                        }
                    }
                }
            }
        }
        if drain_pending[r] && drivers[r].all_done() {
            // The drained replica just went idle: every session placed on
            // it finished (no work lost), and it leaves the GPU-time
            // accounting at the instant of its final event.
            drain_pending[r] = false;
            tracker.set_size(drivers[r].now_us(), tracker.size() - 1);
        }
        if chaos_active {
            // Completion-count termination: every session done and no
            // arrival pending means nothing will ever enqueue again — tell
            // the replicas so their control ticks stop re-arming.
            if !winding_down && done_global == total && queue.is_empty() {
                winding_down = true;
                for d in drivers.iter_mut() {
                    d.set_no_more_arrivals();
                }
            }
        } else if injected == total && drivers[r].all_done() {
            finished[r] = true;
        }
    }
    anyhow::ensure!(
        injected == total && drivers.iter().all(|d| d.all_done()),
        "fleet stalled: {injected}/{total} sessions injected, {} finished \
         (a workflow dependency cycle or router bug)",
        drivers.iter().filter(|d| d.all_done()).count()
    );

    // -- 4) fleet aggregation ----------------------------------------------
    // Raw per-request samples in global session order, so fleet summaries
    // are byte-deterministic and independent of replica interleaving.
    // Harvested (pre-crash) samples precede the finishing replica's — they
    // are chronologically earlier. With chaos off the harvest vectors are
    // empty and this is exactly the legacy gather. Session-joint SLO
    // attainment must span incarnations too (a slow pre-crash request
    // fails the session even if the continuation was fast), so chaos runs
    // re-judge per *global* session here instead of summing the replicas'
    // per-incarnation judgments.
    let mut ttfts: Vec<f64> = Vec::new();
    let mut tpots: Vec<f64> = Vec::new();
    let mut chaos_slo =
        SloReport { sessions: 0, attained: 0, ttft_violations: 0, tpot_violations: 0 };
    for g in 0..total {
        let (from_t, from_p) = (ttfts.len(), tpots.len());
        ttfts.extend_from_slice(&harv_ttfts[g]);
        tpots.extend_from_slice(&harv_tpots[g]);
        let (r, l) = (placements[g], local_of[g]);
        if let Some(s) = drivers[r].recorder().sessions_map().get(&(l as u64)) {
            ttfts.extend_from_slice(&s.ttfts_ms);
            tpots.extend_from_slice(&s.tpots_ms);
        }
        if chaos_active {
            chaos_slo.sessions += 1;
            let ttft_ok = ttfts[from_t..].iter().all(|&t| t <= cfg.slo.ttft_ms);
            let tpot_ok = tpots[from_p..].iter().all(|&t| t <= cfg.slo.tpot_ms);
            if !ttft_ok {
                chaos_slo.ttft_violations += 1;
            }
            if !tpot_ok {
                chaos_slo.tpot_violations += 1;
            }
            if ttft_ok && tpot_ok && session_done[g] {
                chaos_slo.attained += 1;
            }
        }
    }
    // Memory-stall percentiles recomputed from raw samples in global
    // session order — percentiles do not compose across replicas, so the
    // fleet must never max() per-replica p99s (that reads as "worst
    // replica", not "fleet tail").
    for (r, d) in drivers.iter().enumerate() {
        for (l, ms) in d.memory_stalls() {
            harv_stalls[local2global[r][l]].push(ms);
        }
    }
    let stall_flat: Vec<f64> = harv_stalls.iter().flatten().copied().collect();
    let stall_p99_ms = percentile(&stall_flat, 99.0);
    // Host-queue gather: crashed incarnations already merged above; the
    // survivors contribute in replica order. Like stalls, the fleet keeps
    // raw waits and recomputes percentiles once — never max() of p99s.
    for d in drivers.iter() {
        if let Some(s) = d.host_samples() {
            host_acc.merge(&s);
        }
    }

    let wall_us = if track_wall {
        wall_chaos
    } else {
        drivers.iter().map(|d| d.now_us()).max().unwrap_or(0)
    };
    let n_final = drivers.len();
    if record_exec {
        // Live replicas' streams, harvested in replica order; crashed
        // incarnations already contributed theirs at crash time (earlier
        // timestamps, so the final sort is cheap and stable).
        for (r, d) in drivers.iter_mut().enumerate() {
            let mut evs = d.take_exec_events();
            for e in &mut evs {
                e.retag(r as u32, &local2global[r]);
            }
            exec_acc.append(&mut evs);
        }
    }
    let mut per_replica: Vec<SimOutcome> = drivers.into_iter().map(|d| d.finish()).collect();

    // Merge telemetry across every incarnation: surviving replicas first
    // (retagged here — their session maps are still in `local2global`),
    // then the crash-retired ones (retagged at harvest time), then the
    // fleet's own control-plane instants and the fleet-global probe grid.
    let (fleet_obs, fleet_phases) = if obs_active {
        let mut merged = ObsLog::default();
        let mut phases: Option<PhaseReport> = None;
        for (r, o) in per_replica.iter_mut().enumerate() {
            if let Some(mut log) = o.obs.take() {
                // The fleet owns the probe grid; per-replica samplers stay
                // dormant in driver mode.
                log.probes = None;
                log.retag(r as u32, &local2global[r]);
                merged.absorb(log);
            }
            if let Some(p) = o.phases {
                match &mut phases {
                    Some(acc) => acc.merge(&p),
                    None => phases = Some(p),
                }
            }
        }
        for (_, o) in &retired {
            if let Some(log) = &o.obs {
                merged.absorb(log.clone());
            }
            if let Some(p) = o.phases {
                match &mut phases {
                    Some(acc) => acc.merge(&p),
                    None => phases = Some(p),
                }
            }
        }
        if trace_on {
            merged.instants.append(&mut fleet_instants);
        }
        if probe_on {
            merged.probes = Some(ProbeLog {
                interval_us: cfg.obs.probe.interval_us,
                samples: fleet_probes,
            });
        }
        (Some(merged), phases)
    } else {
        (None, None)
    };
    let exec = record_exec.then(|| {
        // Fleet-level TaskDone events go last so they sort after the
        // replica-local events that resolved them on timestamp ties.
        exec_acc.append(&mut fleet_exec);
        exec_acc.sort_by_key(|e| (e.t_us, e.replica));
        ExecTrace { events: exec_acc }
    });

    // Counters sum over the surviving replicas *and* the crashed
    // incarnations — work a replica did before dying still happened.
    let mut slo = SloReport { sessions: 0, attained: 0, ttft_violations: 0, tpot_violations: 0 };
    let mut total_tokens = 0u64;
    let mut completed = 0usize;
    let (mut hit, mut miss, mut evictions, mut preemptions) = (0u64, 0u64, 0u64, 0u64);
    for o in per_replica.iter().chain(retired.iter().map(|(_, o)| o)) {
        slo.sessions += o.slo.sessions;
        slo.attained += o.slo.attained;
        slo.ttft_violations += o.slo.ttft_violations;
        slo.tpot_violations += o.slo.tpot_violations;
        total_tokens += o.report.total_tokens;
        completed += o.report.completed_sessions;
        if let Some(kv) = &o.kv {
            hit += kv.radix_hit_tokens;
            miss += kv.radix_miss_tokens;
            evictions += kv.evictions;
            preemptions += kv.preemptions;
        }
    }
    if chaos_active {
        // A crashed session spans incarnations; the per-replica judgments
        // double-count it. Use the per-global-session re-judgment above.
        slo = chaos_slo;
    }
    let mut per_replica_tokens: Vec<u64> =
        per_replica.iter().map(|o| o.report.total_tokens).collect();
    for (r, o) in &retired {
        per_replica_tokens[*r] += o.report.total_tokens;
    }
    let (wf_tool_retries, wf_failed_tasks) = wf
        .as_ref()
        .map(|w| {
            (
                w.plan.tool_retries,
                w.plan.task_failed.iter().filter(|&&f| f).count() as u64,
            )
        })
        .unwrap_or((0, 0));
    let workflow = wf.map(|w| {
        WorkflowReport::from_task_times(
            &w.plan.task_release_us,
            &w.task_done_us,
            &w.task_cp_ms,
            cfg.slo.task_ms,
            &w.plan.task_failed,
            w.plan.tool_retries,
        )
    });
    let chaos_report = (chaos_active || tool_faults).then(|| ChaosStats {
        tool_retries: wf_tool_retries,
        failed_tasks: wf_failed_tasks,
        ..chaos.map(|c| c.stats).unwrap_or_default()
    });
    // Reported only when the controller actually acted: a configured but
    // never-triggering autoscaler leaves the report byte-identical to the
    // static fleet (the disabled ≡ absent contract, locked in
    // rust/tests/properties.rs).
    let autoscale_report = (as_ups + as_downs > 0).then(|| {
        let final_replicas = tracker.size();
        let (replica_us, time_at_size_us) = tracker.finish(wall_us);
        AutoscaleStats {
            scale_ups: as_ups,
            scale_downs: as_downs,
            peak_replicas: time_at_size_us.len() - 1,
            final_replicas,
            replica_us,
            time_at_size_us,
        }
    });
    // Fleet host capacity approximates every final replica as present for
    // the whole wall clock (autoscaled fleets overstate capacity for
    // late-booted replicas — documented in docs/ARCHITECTURE.md).
    let host_report = cfg.host.is_active().then(|| {
        HostReport::from_samples(
            cfg.host.cpu_workers,
            &host_acc,
            cfg.host.cpu_workers as u64 * wall_us * n_final as u64,
        )
    });
    let wall_ms = wall_us as f64 / 1000.0;
    let wall_s = (wall_ms / 1000.0).max(1e-9);
    let report = FleetReport {
        replicas: n_final,
        router: router_policy.name().to_string(),
        sessions: total,
        completed_sessions: completed,
        total_tokens,
        wall_ms,
        throughput_tok_s: total_tokens as f64 / wall_s,
        ttft: Summary::from_samples(&ttfts),
        tpot: Summary::from_samples(&tpots),
        slo,
        load_cov: load_cov(&per_replica_tokens),
        per_replica_tokens,
        affinity_hits: router.affinity_hits,
        affinity_opportunities: router.affinity_opportunities,
        radix_hit_tokens: hit,
        radix_miss_tokens: miss,
        evictions,
        preemptions,
        stall_p99_ms,
        kv_present: cfg.kv.is_paged(),
        workflow,
        chaos: chaos_report,
        autoscale: autoscale_report,
        host: host_report,
        phases: fleet_phases,
    };
    Ok(FleetOutcome {
        policy_name: policy.name().to_string(),
        router: router_policy,
        replicas: n_final,
        report,
        per_replica,
        placements,
        obs: fleet_obs,
        exec,
    })
}
