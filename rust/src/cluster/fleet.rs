//! The fleet loop: N replica simulators on one shared virtual clock behind
//! a session router.
//!
//! A fleet run is a deterministic three-way merge:
//!
//! 1. **Fleet arrivals** — the scenario's arrival plan, plus arrivals the
//!    run itself creates: closed-loop agents chain their next session
//!    `think_time` after the previous completes, and workflow dependents
//!    are released when their fleet-wide join barrier resolves. Each
//!    arrival is routed *at its timestamp* against the replicas' live load
//!    surfaces and injected into the chosen [`SimDriver`].
//! 2. **Replica events** — each replica advances one event at a time; the
//!    loop always processes the globally earliest thing (arrivals win
//!    exact-timestamp ties, mirroring the simulator's low sequence band
//!    for injected arrivals; replica ties resolve by index).
//! 3. **Completions** — burst/session completions drain back to the fleet
//!    after every step, resolving workflow gates *fleet-wide*: a join's
//!    workers may live on different replicas than the supervisor they
//!    release ([`SimDriver::open_step_gate`]).
//!
//! With one replica and an open-loop scenario this machinery collapses to
//! exactly the batch event order, so `run_cluster(.., 1, ..)` reproduces
//! [`crate::engine::run_scenario`] byte-for-byte under every router — the
//! lock that keeps the `SimDriver` refactor a pure refactor
//! (`rust/tests/cluster.rs`). Closed-loop and workflow scenarios re-route
//! fleet-created arrivals at their own timestamps, which can order
//! differently from the batch path only when such an arrival collides with
//! an internal event on the exact microsecond (see
//! `docs/ARCHITECTURE.md` § Fleet layer).

use super::router::Router;
use crate::config::{Config, RouterPolicy};
use crate::engine::sim::task_critical_paths_ms;
use crate::engine::{DriverEvent, Policy, SimDriver, SimOutcome};
use crate::gpusim::CostModel;
use crate::metrics::{load_cov, FleetReport, SloReport, Summary, WorkflowReport};
use crate::workflow::WorkflowPlan;
use crate::workload::{Scenario, SessionScript};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

/// Results of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub policy_name: String,
    pub router: RouterPolicy,
    pub replicas: usize,
    /// Fleet-level aggregation (the headline surface).
    pub report: FleetReport,
    /// Each replica's own outcome, in replica order.
    pub per_replica: Vec<SimOutcome>,
    /// Replica index per global session (the routing record).
    pub placements: Vec<usize>,
}

/// Fleet-side workflow orchestration: gate counters over the compiled
/// [`WorkflowPlan`], resolved from completions across *all* replicas.
struct WfFleet {
    plan: WorkflowPlan,
    /// Unresolved arrival-gate dependencies per session.
    arr_remaining: Vec<usize>,
    /// Unresolved step-gate dependencies per (session, step).
    step_remaining: Vec<Vec<usize>>,
    /// Unfinished sessions per task.
    task_left: Vec<usize>,
    /// Completion timestamp per task.
    task_done_us: Vec<Option<u64>>,
    /// Ideal critical-path lower bound per task (ms) — same cost model on
    /// every (homogeneous) replica.
    task_cp_ms: Vec<f64>,
}

/// Run a scenario on an `n_replicas`-GPU fleet under `router` (timeline
/// retained per replica, like [`crate::engine::run_scenario`]).
pub fn run_cluster(
    cfg: &Config,
    policy: Policy,
    scenario: &Scenario,
    n_replicas: usize,
    router: RouterPolicy,
    seed: u64,
) -> crate::Result<FleetOutcome> {
    run_cluster_inner(cfg, policy, scenario, n_replicas, router, seed, false)
}

/// [`run_cluster`] without per-token timeline retention — the fleet-sweep
/// hot path. Aggregates are byte-identical to [`run_cluster`].
pub fn run_cluster_fast(
    cfg: &Config,
    policy: Policy,
    scenario: &Scenario,
    n_replicas: usize,
    router: RouterPolicy,
    seed: u64,
) -> crate::Result<FleetOutcome> {
    run_cluster_inner(cfg, policy, scenario, n_replicas, router, seed, true)
}

/// The affinity-unit key of one global session: closed-loop agent slot, or
/// owning workflow task. Independent open-loop sessions have none.
fn unit_key(g: usize, chain: Option<(usize, u64)>, wf: Option<&WfFleet>) -> Option<u64> {
    if let Some((stride, _)) = chain {
        return Some((g % stride) as u64);
    }
    wf.map(|w| w.plan.task_of[g] as u64)
}

fn run_cluster_inner(
    cfg: &Config,
    policy: Policy,
    scenario: &Scenario,
    n_replicas: usize,
    router_policy: RouterPolicy,
    seed: u64,
    fast: bool,
) -> crate::Result<FleetOutcome> {
    anyhow::ensure!(n_replicas >= 1, "a fleet needs at least one replica");
    scenario.validate()?;
    let cfg = scenario.effective_config(cfg);

    // -- 1) lower the scenario into scripts + the fleet arrival plan --------
    // `chain` = closed-loop chaining (stride, think time); `wf` = fleet-wide
    // workflow gates. `seeds` are the unconditional (wave-0 / root /
    // open-loop) arrivals in session-index order.
    let mut chain: Option<(usize, u64)> = None;
    let mut wf: Option<WfFleet> = None;
    let (scripts, seeds): (Vec<SessionScript>, Vec<(usize, u64)>) = if scenario.workflow.is_some()
    {
        let cw = crate::workflow::compile(scenario, cfg.model.kind, seed);
        let cost = CostModel::new(&cfg.model, &cfg.gpu);
        let seeds = cw.plan.root_arrivals();
        // Same gate initialization as the in-simulator WfState — both sides
        // call the shared WorkflowPlan helpers, so semantics cannot drift.
        wf = Some(WfFleet {
            arr_remaining: cw.plan.initial_arrival_gates(),
            step_remaining: cw.plan.initial_step_gates(),
            task_left: cw.plan.task_session_counts(),
            task_done_us: vec![None; cw.plan.n_tasks],
            task_cp_ms: task_critical_paths_ms(&cost, &cw.scripts, &cw.plan),
            plan: cw.plan,
        });
        (cw.scripts, seeds)
    } else {
        let wl = scenario.instantiate(cfg.model.kind, seed);
        let (scripts, arrivals): (Vec<_>, Vec<_>) = wl
            .trace
            .events
            .into_iter()
            .map(|e| (e.script, e.arrival_us))
            .unzip();
        let seeds = match scenario.closed_loop() {
            Some((stagger_us, think_time_us)) => {
                // Wave 0 staggered across the agent slots; waves > 0 chain
                // at fleet level (each re-routed at its arrival timestamp).
                let slots = scenario.n_agents.max(1);
                chain = Some((slots, think_time_us));
                (0..slots.min(scripts.len()))
                    .map(|a| (a, a as u64 * stagger_us))
                    .collect()
            }
            None => arrivals.iter().copied().enumerate().collect(),
        };
        (scripts, seeds)
    };
    let total = scripts.len();

    // -- 2) replicas, router, fleet arrival queue ---------------------------
    let mut drivers: Vec<SimDriver> = (0..n_replicas)
        .map(|_| {
            if fast {
                SimDriver::new_fast(&cfg, policy)
            } else {
                SimDriver::new(&cfg, policy)
            }
        })
        .collect();
    let mut router = Router::new(router_policy);
    // (time, fleet-seq, global session): seq makes equal-time arrivals pop
    // in creation order — seed order first, then fleet-created arrivals.
    let mut queue: BinaryHeap<Reverse<(u64, u64, usize)>> = BinaryHeap::new();
    let mut fseq: u64 = 0;
    for &(g, t) in &seeds {
        queue.push(Reverse((t, fseq, g)));
        fseq += 1;
    }

    let mut placements = vec![usize::MAX; total];
    let mut local_of = vec![usize::MAX; total];
    let mut local2global: Vec<Vec<usize>> = vec![Vec::new(); n_replicas];
    let mut injected = 0usize;
    let mut finished = vec![false; n_replicas];
    let mut events: Vec<DriverEvent> = Vec::new();
    // Prompt ids are only materialized when the cache-aware router can use
    // them (radix cache live on the paged path with sharing on). Same-
    // template prompts are one deterministic stream — a shorter prompt is a
    // prefix of a longer one — so the longest materialized vector per
    // template is cached and sliced instead of regenerated per arrival
    // (sessions with per-task unique suffixes bypass the cache).
    let want_prompt =
        router_policy == RouterPolicy::CacheAware && cfg.kv.is_paged() && cfg.kv.prefix_sharing;
    let mut prompt_cache: BTreeMap<u32, Vec<u32>> = BTreeMap::new();

    // -- 3) the lockstep merge loop ----------------------------------------
    loop {
        let t_arr = queue.peek().map(|Reverse((t, _, _))| *t);
        let mut t_rep: Option<(u64, usize)> = None;
        for (r, d) in drivers.iter().enumerate() {
            if finished[r] {
                continue;
            }
            if let Some(t) = d.next_event_us() {
                if t_rep.is_none_or(|(bt, _)| t < bt) {
                    t_rep = Some((t, r));
                }
            }
        }
        // Arrivals win exact-time ties: injected arrivals sit in the low
        // sequence band of the replica heap, so the replica would order
        // them first anyway — the fleet must have routed them by then.
        let take_arrival = match (t_arr, t_rep) {
            (None, None) => break,
            (Some(ta), Some((tr, _))) => ta <= tr,
            (Some(_), None) => true,
            (None, Some(_)) => false,
        };
        if take_arrival {
            let Reverse((t, _, g)) = queue.pop().expect("peeked above");
            let unit = unit_key(g, chain, wf.as_ref());
            let unique_buf: Vec<u32>;
            let prompt: Option<&[u32]> = if want_prompt {
                let s = &scripts[g];
                if s.unique_prompt_tokens == 0 {
                    let entry = prompt_cache.entry(s.template).or_default();
                    if entry.len() < s.cold_prefill_tokens as usize {
                        *entry = s.system_prompt_ids();
                    }
                    Some(&entry[..s.cold_prefill_tokens as usize])
                } else {
                    unique_buf = s.system_prompt_ids();
                    Some(&unique_buf)
                }
            } else {
                None
            };
            let r = router.route(unit, prompt, &drivers);
            let gated: Vec<usize> = wf
                .as_ref()
                .map(|w| {
                    w.step_remaining[g]
                        .iter()
                        .enumerate()
                        .filter(|&(_, &c)| c > 0)
                        .map(|(i, _)| i)
                        .collect()
                })
                .unwrap_or_default();
            let local = drivers[r].inject(scripts[g].clone(), t, &gated);
            debug_assert_eq!(local, local2global[r].len());
            placements[g] = r;
            local_of[g] = local;
            local2global[r].push(g);
            injected += 1;
            if injected == total {
                for (r, d) in drivers.iter_mut().enumerate() {
                    d.set_no_more_arrivals();
                    finished[r] = d.all_done(); // replicas that got nothing
                }
            }
            continue;
        }
        let (_, r) = t_rep.expect("one side is Some");
        if !drivers[r].step() {
            finished[r] = true;
            continue;
        }
        drivers[r].drain_events(&mut events);
        for ev in events.drain(..) {
            match ev {
                DriverEvent::BurstDone { sess, burst, t_us } => {
                    let g = local2global[r][sess];
                    let Some(w) = &mut wf else { continue };
                    // One shared implementation of the decrement/release
                    // semantics (WorkflowPlan::resolve_burst) — the fleet
                    // only differs in *where* releases go: arrivals into
                    // the router queue, step gates onto the holding replica.
                    let resolved = w.plan.resolve_burst(
                        g,
                        burst,
                        &mut w.arr_remaining,
                        &mut w.step_remaining,
                    );
                    for (s2, delay) in resolved.arrivals {
                        queue.push(Reverse((t_us + delay, fseq, s2)));
                        fseq += 1;
                    }
                    for (s2, step) in resolved.steps {
                        // Wake the (possibly parked) session on whichever
                        // replica holds it; a target not yet injected
                        // simply arrives with this gate already open.
                        if placements[s2] != usize::MAX {
                            drivers[placements[s2]].open_step_gate(local_of[s2], step, t_us);
                        }
                    }
                }
                DriverEvent::SessionDone { sess, t_us } => {
                    let g = local2global[r][sess];
                    if let Some((stride, think_us)) = chain {
                        let next = g + stride;
                        if next < total {
                            queue.push(Reverse((t_us + think_us, fseq, next)));
                            fseq += 1;
                        }
                    }
                    if let Some(w) = &mut wf {
                        let task = w.plan.task_of[g];
                        w.task_left[task] -= 1;
                        if w.task_left[task] == 0 {
                            w.task_done_us[task] = Some(t_us);
                        }
                    }
                }
            }
        }
        if injected == total && drivers[r].all_done() {
            finished[r] = true;
        }
    }
    anyhow::ensure!(
        injected == total && drivers.iter().all(|d| d.all_done()),
        "fleet stalled: {injected}/{total} sessions injected, {} finished \
         (a workflow dependency cycle or router bug)",
        drivers.iter().filter(|d| d.all_done()).count()
    );

    // -- 4) fleet aggregation ----------------------------------------------
    // Raw per-request samples in global session order, so fleet summaries
    // are byte-deterministic and independent of replica interleaving.
    let mut ttfts: Vec<f64> = Vec::new();
    let mut tpots: Vec<f64> = Vec::new();
    for g in 0..total {
        let (r, l) = (placements[g], local_of[g]);
        if let Some(s) = drivers[r].recorder().sessions_map().get(&(l as u64)) {
            ttfts.extend_from_slice(&s.ttfts_ms);
            tpots.extend_from_slice(&s.tpots_ms);
        }
    }
    let wall_us = drivers.iter().map(|d| d.now_us()).max().unwrap_or(0);
    let per_replica: Vec<SimOutcome> = drivers.into_iter().map(|d| d.finish()).collect();

    let mut slo = SloReport { sessions: 0, attained: 0, ttft_violations: 0, tpot_violations: 0 };
    let mut total_tokens = 0u64;
    let mut completed = 0usize;
    let mut per_replica_tokens = Vec::with_capacity(per_replica.len());
    let (mut hit, mut miss, mut evictions, mut preemptions) = (0u64, 0u64, 0u64, 0u64);
    let mut stall_p99_ms = 0.0f64;
    for o in &per_replica {
        slo.sessions += o.slo.sessions;
        slo.attained += o.slo.attained;
        slo.ttft_violations += o.slo.ttft_violations;
        slo.tpot_violations += o.slo.tpot_violations;
        total_tokens += o.report.total_tokens;
        completed += o.report.completed_sessions;
        per_replica_tokens.push(o.report.total_tokens);
        if let Some(kv) = &o.kv {
            hit += kv.radix_hit_tokens;
            miss += kv.radix_miss_tokens;
            evictions += kv.evictions;
            preemptions += kv.preemptions;
            stall_p99_ms = stall_p99_ms.max(kv.stalls.p99);
        }
    }
    let workflow = wf.map(|w| {
        WorkflowReport::from_task_times(
            &w.plan.task_release_us,
            &w.task_done_us,
            &w.task_cp_ms,
            cfg.slo.task_ms,
        )
    });
    let wall_ms = wall_us as f64 / 1000.0;
    let wall_s = (wall_ms / 1000.0).max(1e-9);
    let report = FleetReport {
        replicas: n_replicas,
        router: router_policy.name().to_string(),
        sessions: total,
        completed_sessions: completed,
        total_tokens,
        wall_ms,
        throughput_tok_s: total_tokens as f64 / wall_s,
        ttft: Summary::from_samples(&ttfts),
        tpot: Summary::from_samples(&tpots),
        slo,
        load_cov: load_cov(&per_replica_tokens),
        per_replica_tokens,
        affinity_hits: router.affinity_hits,
        affinity_opportunities: router.affinity_opportunities,
        radix_hit_tokens: hit,
        radix_miss_tokens: miss,
        evictions,
        preemptions,
        stall_p99_ms,
        kv_present: cfg.kv.is_paged(),
        workflow,
    };
    Ok(FleetOutcome {
        policy_name: policy.name().to_string(),
        router: router_policy,
        replicas: n_replicas,
        report,
        per_replica,
        placements,
    })
}
