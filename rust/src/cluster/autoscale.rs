//! Deterministic fleet autoscaler: the control plane over the replica
//! fleet ([`super::fleet`]).
//!
//! The fleet loop calls [`Autoscaler::tick`] at every control instant on
//! the virtual clock (the tick source loses timestamp ties to chaos events
//! and arrivals — see the merge-order contract in `fleet.rs`). Each tick
//! smooths the fleet's mean per-replica pressure
//! ([`crate::engine::ReplicaLoad::pressure`]) with an EWMA and applies
//! hysteresis: the smoothed signal must hold past a threshold for
//! `sustain_ticks` consecutive ticks before the controller acts, scale-down
//! additionally waits out `cooldown_us` since the last scale event, and no
//! decision fires while a previously ordered boot is still cold. The
//! controller is pure state-machine arithmetic — no RNG, no wall clock —
//! so fleet size is a pure function of `(seed, scenario, config)`.
//!
//! [`SizeTracker`] integrates fleet size over virtual time for the cost
//! side of the cost-vs-SLO frontier: `replica_us` (the GPU-time integral
//! Σ size × dt) and a time-at-each-size histogram, both surfaced in
//! [`crate::metrics::AutoscaleStats`].

use crate::config::AutoscaleConfig;

/// EWMA smoothing factor for the load signal (weight of the newest
/// sample). 0.5 keeps ~two ticks of memory — enough to ride out a single
/// quiet tick inside a burst without delaying real phase shifts.
const EWMA_ALPHA: f64 = 0.5;

/// What the controller ordered at one tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Hold the current size.
    Hold,
    /// Boot one replica (cold start: `boot_us` of model load, empty cache).
    Up,
    /// Drain one replica (it finishes placed work, then leaves the
    /// accounting — no tokens are lost).
    Down,
}

/// The hysteresis state machine. One instance per fleet run; the fleet
/// loop owns the clock and calls [`Autoscaler::tick`] exactly at
/// [`Autoscaler::next_tick_us`].
#[derive(Debug, Clone)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    next_tick_us: u64,
    /// Smoothed signal (`None` until the first tick seeds it).
    ewma: Option<f64>,
    ticks_above: u32,
    ticks_below: u32,
    /// Virtual time of the last scale order (0 = never — the run start
    /// counts as the reference point, so an early scale-down still waits
    /// out one full cooldown).
    last_scale_us: u64,
    scale_ups: u64,
    scale_downs: u64,
}

impl Autoscaler {
    /// `cfg` must be active and validated (the fleet loop checks).
    pub fn new(cfg: AutoscaleConfig) -> Self {
        debug_assert!(cfg.is_active());
        let first = cfg.interval_us;
        Self {
            cfg,
            next_tick_us: first,
            ewma: None,
            ticks_above: 0,
            ticks_below: 0,
            last_scale_us: 0,
            scale_ups: 0,
            scale_downs: 0,
        }
    }

    /// Virtual instant of the next control tick.
    pub fn next_tick_us(&self) -> u64 {
        self.next_tick_us
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Scale orders issued so far, as `(ups, downs)`.
    pub fn events(&self) -> (u64, u64) {
        (self.scale_ups, self.scale_downs)
    }

    /// Current smoothed signal (for diagnostics; `None` before any tick).
    pub fn smoothed(&self) -> Option<f64> {
        self.ewma
    }

    /// One control tick at virtual time `now` (must equal
    /// [`Self::next_tick_us`]). `signal` is the mean serving-replica
    /// pressure, `size` the current accounted fleet size, `booting` the
    /// number of ordered-but-cold replicas.
    pub fn tick(&mut self, now: u64, signal: f64, size: usize, booting: usize) -> ScaleDecision {
        debug_assert_eq!(now, self.next_tick_us);
        self.next_tick_us = now + self.cfg.interval_us;
        let prev = self.ewma.unwrap_or(signal);
        let smoothed = EWMA_ALPHA * signal + (1.0 - EWMA_ALPHA) * prev;
        self.ewma = Some(smoothed);
        if smoothed > self.cfg.up_thresh {
            self.ticks_above += 1;
        } else {
            self.ticks_above = 0;
        }
        if smoothed < self.cfg.down_thresh {
            self.ticks_below += 1;
        } else {
            self.ticks_below = 0;
        }
        // Never stack decisions on a cold boot: the new replica has not
        // absorbed any load yet, so acting again would double-count the
        // pressure that ordered it. Sustain restarts once the boot lands.
        if booting > 0 {
            self.ticks_above = 0;
            self.ticks_below = 0;
            return ScaleDecision::Hold;
        }
        if self.ticks_above >= self.cfg.sustain_ticks && size < self.cfg.max_replicas {
            self.ticks_above = 0;
            self.ticks_below = 0;
            self.last_scale_us = now;
            self.scale_ups += 1;
            return ScaleDecision::Up;
        }
        let cooled = now.saturating_sub(self.last_scale_us) >= self.cfg.cooldown_us;
        if self.ticks_below >= self.cfg.sustain_ticks && size > self.cfg.min_replicas && cooled {
            self.ticks_above = 0;
            self.ticks_below = 0;
            self.last_scale_us = now;
            self.scale_downs += 1;
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }
}

/// Integrates fleet size over virtual time: the GPU-cost side of the
/// cost-vs-SLO frontier. A replica counts from the instant its boot is
/// ordered (the GPU is held from then on) until it actually leaves — for a
/// drain, the instant the fleet observes it idle.
#[derive(Debug, Clone)]
pub struct SizeTracker {
    last_us: u64,
    size: usize,
    /// Σ size × dt (replica-microseconds).
    replica_us: u64,
    /// Virtual time spent at each fleet size (`at_size_us[k]` = time at
    /// size `k`; index 0 stays 0 for a live fleet).
    at_size_us: Vec<u64>,
}

impl SizeTracker {
    pub fn new(initial_size: usize) -> Self {
        Self {
            last_us: 0,
            size: initial_size,
            replica_us: 0,
            at_size_us: vec![0; initial_size + 1],
        }
    }

    /// Account elapsed time at the current size up to `now`. Idempotent at
    /// one instant; `now` earlier than the last accounting is a no-op
    /// (saturating — replica completions can be observed out of order
    /// across the merge).
    pub fn advance(&mut self, now: u64) {
        let dt = now.saturating_sub(self.last_us);
        if dt == 0 {
            return;
        }
        self.replica_us += self.size as u64 * dt;
        if self.size >= self.at_size_us.len() {
            self.at_size_us.resize(self.size + 1, 0);
        }
        self.at_size_us[self.size] += dt;
        self.last_us = self.last_us.max(now);
    }

    /// Account up to `now`, then change the fleet size.
    pub fn set_size(&mut self, now: u64, size: usize) {
        self.advance(now);
        self.size = size;
        if size >= self.at_size_us.len() {
            self.at_size_us.resize(size + 1, 0);
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Finalize at `end_us` and read out `(replica_us, at_size_us)`.
    pub fn finish(mut self, end_us: u64) -> (u64, Vec<u64>) {
        self.advance(end_us);
        (self.replica_us, self.at_size_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            interval_us: 100,
            min_replicas: 1,
            max_replicas: 3,
            up_thresh: 2.0,
            down_thresh: 0.5,
            sustain_ticks: 2,
            cooldown_us: 300,
            boot_us: 50,
        }
    }

    #[test]
    fn scale_up_needs_sustained_pressure() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.next_tick_us(), 100);
        // One hot tick is not enough (sustain_ticks = 2).
        assert_eq!(a.tick(100, 10.0, 1, 0), ScaleDecision::Hold);
        assert_eq!(a.tick(200, 10.0, 1, 0), ScaleDecision::Up);
        assert_eq!(a.events(), (1, 0));
        // Counters reset after the order: the next hot tick starts over.
        assert_eq!(a.tick(300, 10.0, 2, 1), ScaleDecision::Hold, "boot pending");
        assert_eq!(a.tick(400, 10.0, 2, 0), ScaleDecision::Hold, "sustain restarts");
        assert_eq!(a.tick(500, 10.0, 2, 0), ScaleDecision::Up);
        // At max size the controller holds no matter the pressure.
        assert_eq!(a.tick(600, 10.0, 3, 0), ScaleDecision::Hold);
        assert_eq!(a.tick(700, 10.0, 3, 0), ScaleDecision::Hold);
        assert_eq!(a.events(), (2, 0));
    }

    #[test]
    fn ewma_debounces_single_tick_spikes() {
        let mut a = Autoscaler::new(cfg());
        // A lone spike between idle ticks never sustains past the
        // threshold: ewma(0, 10, 0, ...) crosses once, then falls back.
        assert_eq!(a.tick(100, 0.0, 1, 0), ScaleDecision::Hold);
        assert_eq!(a.tick(200, 10.0, 1, 0), ScaleDecision::Hold);
        assert_eq!(a.tick(300, 0.0, 1, 0), ScaleDecision::Hold);
        assert_eq!(a.tick(400, 0.0, 1, 0), ScaleDecision::Hold);
        assert_eq!(a.events(), (0, 0));
    }

    #[test]
    fn scale_down_waits_out_cooldown_and_floor() {
        let mut a = Autoscaler::new(cfg());
        // Idle from the start: sustain is met at t=200 but cooldown (300 us
        // from t=0) holds the order until t=300.
        assert_eq!(a.tick(100, 0.0, 2, 0), ScaleDecision::Hold);
        assert_eq!(a.tick(200, 0.0, 2, 0), ScaleDecision::Hold);
        assert_eq!(a.tick(300, 0.0, 2, 0), ScaleDecision::Down);
        assert_eq!(a.events(), (0, 1));
        // At the floor the controller never drains below min_replicas.
        assert_eq!(a.tick(400, 0.0, 1, 0), ScaleDecision::Hold);
        assert_eq!(a.tick(500, 0.0, 1, 0), ScaleDecision::Hold);
        assert_eq!(a.tick(600, 0.0, 1, 0), ScaleDecision::Hold);
        assert_eq!(a.events(), (0, 1));
    }

    #[test]
    fn controller_is_deterministic() {
        let run = || {
            let mut a = Autoscaler::new(cfg());
            let signals = [0.0, 5.0, 5.0, 5.0, 0.2, 0.0, 0.0, 0.0, 0.0];
            let mut size = 1usize;
            let mut orders = Vec::new();
            for (i, &s) in signals.iter().enumerate() {
                let t = 100 * (i as u64 + 1);
                let d = a.tick(t, s, size, 0);
                match d {
                    ScaleDecision::Up => size += 1,
                    ScaleDecision::Down => size -= 1,
                    ScaleDecision::Hold => {}
                }
                orders.push((t, d, size));
            }
            orders
        };
        assert_eq!(run(), run(), "same inputs, same orders");
    }

    #[test]
    fn size_tracker_integrates_exactly() {
        let mut t = SizeTracker::new(1);
        t.set_size(100, 2); // 100 us at size 1
        t.set_size(300, 1); // 200 us at size 2
        t.advance(250); // stale advance: no-op (250 < 300)
        let (replica_us, hist) = t.finish(600); // 300 us at size 1
        assert_eq!(replica_us, 100 + 2 * 200 + 300);
        assert_eq!(hist[1], 400);
        assert_eq!(hist[2], 200);
        assert_eq!(hist.iter().sum::<u64>(), 600, "histogram covers the run");
    }
}
