//! # AgentServe
//!
//! Reproduction of *AgentServe: Algorithm-System Co-Design for Efficient
//! Agentic AI Serving on a Consumer-Grade GPU* (CS.DC 2026).
//!
//! AgentServe serves multiple tool-augmented SLM agents on a single GPU by
//! classifying requests into **cold prefills**, **resume prefills**, and
//! **short decodes**, isolating cold prefills, admitting resume prefills
//! under a dynamic token budget, and protecting decodes with SM reservations
//! realised through pre-established Green Context slots.
//!
//! The crate is organised as a three-layer stack:
//! - L3 (this crate): coordinator, scheduler, KV cache, execution engine.
//! - L2 (`python/compile/model.py`): JAX transformer, AOT-lowered to HLO
//!   text loaded by [`runtime`].
//! - L1 (`python/compile/kernels/`): Pallas attention kernels.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping modules to paper figures.

pub mod agents;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod gpusim;
pub mod greenctx;
pub mod host;
pub mod kvcache;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workflow;
pub mod workload;

/// Crate-wide result type (anyhow — the only general-purpose dependency
/// available in the offline build image; see `rust/src/util` for the
/// in-tree JSON/RNG/CLI/bench substrates).
pub type Result<T> = anyhow::Result<T>;
