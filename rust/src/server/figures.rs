//! Paper table/figure regeneration harness (deliverable d).
//!
//! One function per evaluation artifact; each prints the same rows/series
//! the paper reports and can dump JSON for plotting. Absolute values come
//! from our calibrated cost model, so the claim under test is the *shape*:
//! who wins, by roughly what factor, and where crossovers fall
//! (EXPERIMENTS.md records paper-vs-measured for each).

use crate::config::{Config, GpuKind, ModelKind};
use crate::coordinator::CompetitiveAnalyzer;
use crate::engine::{run_sim, Policy, SimOutcome, SimParams};
use crate::gpusim::{CostModel, Phase};
use crate::greenctx::GreenContextPool;
use crate::util::json::Value;
use crate::workload::{DistSummary, TokenStats, WorkloadGenerator, WorkloadKind};

fn dump_json(json_dir: Option<&str>, name: &str, value: &Value) -> crate::Result<()> {
    if let Some(dir) = json_dir {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join(format!("{name}.json"));
        std::fs::write(path, value.to_string_pretty())?;
    }
    Ok(())
}

fn dist_value(d: &DistSummary) -> Value {
    Value::obj(vec![
        ("min", d.min.into()),
        ("max", d.max.into()),
        ("mean", d.mean.into()),
        ("n", d.n.into()),
    ])
}

/// Fig. 2: TPOT timeline of mixed execution — cold prefills overlapping
/// decodes cause emission-latency spikes (Qwen-3B/7B, A5000, 3 agents).
pub fn fig2_tpot_timeline(json_dir: Option<&str>) -> crate::Result<()> {
    println!("\n=== Figure 2: TPOT timeline under mixed execution (A5000, 3 agents) ===");
    let mut all = Vec::new();
    for model in [ModelKind::Qwen3B, ModelKind::Qwen7B] {
        let cfg = Config::preset(model, GpuKind::A5000);
        let params = SimParams {
            n_agents: 3,
            sessions_per_agent: 2,
            workload: WorkloadKind::ReAct,
            ..SimParams::default()
        };
        let out = run_sim(&cfg, Policy::LlamaCpp, &params);
        let spikes: Vec<&crate::metrics::TpotSample> = out
            .timeline
            .iter()
            .filter(|s| s.gap_ms > 4.0 * out.report.tpot.p50)
            .collect();
        println!(
            "{model}: {} tokens, TPOT p50 {:.1} ms, p95 {:.1} ms, max {:.1} ms, {} spikes (> 4x p50)",
            out.timeline.len(),
            out.report.tpot.p50,
            out.report.tpot.p95,
            out.report.tpot.max,
            spikes.len()
        );
        for s in spikes.iter().take(5) {
            println!(
                "   spike at t={:.1}s: {:.0} ms gap (agent {})",
                s.t_us as f64 / 1e6,
                s.gap_ms,
                s.session
            );
        }
        let series: Vec<Value> = out
            .timeline
            .iter()
            .map(|s| Value::Arr(vec![s.t_us.into(), s.gap_ms.into()]))
            .collect();
        all.push((
            model.name().to_string(),
            Value::obj(vec![
                ("series", Value::Arr(series)),
                ("p50", out.report.tpot.p50.into()),
                ("p95", out.report.tpot.p95.into()),
            ]),
        ));
    }
    println!("(paper: sharp TPOT spikes appear when heavy prefills overlap active decodes)");
    dump_json(json_dir, "fig2", &Value::Obj(all))
}

/// Fig. 3: normalized throughput vs SM share per phase (Qwen-3B/7B, 5090).
pub fn fig3_sm_curves(json_dir: Option<&str>) -> crate::Result<()> {
    println!("\n=== Figure 3: normalized throughput vs SM share (RTX 5090) ===");
    let mut all = Vec::new();
    for model in [ModelKind::Qwen3B, ModelKind::Qwen7B] {
        let cfg = Config::preset(model, GpuKind::Rtx5090);
        let cost = CostModel::new(&cfg.model, &cfg.gpu);
        println!("{model}:   share   decode  resume   cold");
        let mut rows = Vec::new();
        let full_d = cost.decode_throughput(4, 12_000, 1.0);
        let full_r = cost.prefill_throughput(128, 1.0, Phase::ResumePrefill);
        let full_c = cost.prefill_throughput(3000, 1.0, Phase::ColdPrefill);
        for i in 1..=10 {
            let x = i as f64 / 10.0;
            let d = cost.decode_throughput(4, 12_000, x) / full_d;
            let r = cost.prefill_throughput(128, x, Phase::ResumePrefill) / full_r;
            let c = cost.prefill_throughput(3000, x, Phase::ColdPrefill) / full_c;
            println!("          {:>4.0}%   {:>5.2}   {:>5.2}  {:>5.2}", x * 100.0, d, r, c);
            rows.push(Value::obj(vec![
                ("share", x.into()),
                ("decode", d.into()),
                ("resume", r.into()),
                ("cold", c.into()),
            ]));
        }
        all.push((model.name().to_string(), Value::Arr(rows)));
    }
    println!("(paper: decode saturates earliest, cold prefill scales most gradually, resume in between)");
    dump_json(json_dir, "fig3", &Value::Obj(all))
}

/// The Fig. 5/6 grid: every (model, gpu, concurrency, policy) cell.
pub fn run_grid() -> Vec<(ModelKind, GpuKind, usize, SimOutcome)> {
    let mut cells = Vec::new();
    for model in ModelKind::ALL {
        for gpu in GpuKind::ALL {
            let cfg = Config::preset(model, gpu);
            for n in 3..=6 {
                for policy in Policy::paper_lineup() {
                    let params = SimParams {
                        n_agents: n,
                        sessions_per_agent: 2,
                        workload: WorkloadKind::ReAct,
                        ..SimParams::default()
                    };
                    cells.push((model, gpu, n, run_sim(&cfg, policy, &params)));
                }
            }
        }
    }
    cells
}

/// Fig. 5: TTFT/TPOT (p50, p95) and throughput across the full grid.
pub fn fig5_latency_throughput(json_dir: Option<&str>) -> crate::Result<()> {
    println!("\n=== Figure 5: latency & throughput across model-device settings ===");
    let cells = run_grid();
    let mut rows = Vec::new();
    for model in ModelKind::ALL {
        for gpu in GpuKind::ALL {
            println!("\n--- {model} on {gpu} ---");
            println!(
                "{:<11} {:>2}  {:>9} {:>9}  {:>8} {:>8}  {:>9}",
                "policy", "N", "TTFT p50", "TTFT p95", "TPOT p50", "TPOT p95", "tok/s"
            );
            for n in 3..=6 {
                for (m, g, nn, out) in &cells {
                    if *m == model && *g == gpu && *nn == n {
                        println!(
                            "{:<11} {:>2}  {:>8.0}ms {:>8.0}ms  {:>7.1}ms {:>7.1}ms  {:>9.1}",
                            out.policy_name,
                            n,
                            out.report.ttft.p50,
                            out.report.ttft.p95,
                            out.report.tpot.p50,
                            out.report.tpot.p95,
                            out.report.throughput_tok_s
                        );
                        rows.push(Value::obj(vec![
                            ("model", m.name().into()),
                            ("gpu", g.name().into()),
                            ("agents", (*nn).into()),
                            ("policy", out.policy_name.as_str().into()),
                            ("ttft_p50", out.report.ttft.p50.into()),
                            ("ttft_p95", out.report.ttft.p95.into()),
                            ("tpot_p50", out.report.tpot.p50.into()),
                            ("tpot_p95", out.report.tpot.p95.into()),
                            ("throughput", out.report.throughput_tok_s.into()),
                        ]));
                    }
                }
            }
        }
    }
    summarize_ratios(&cells);
    dump_json(json_dir, "fig5", &Value::Arr(rows))
}

fn summarize_ratios(cells: &[(ModelKind, GpuKind, usize, SimOutcome)]) {
    let mut best: Vec<(&str, f64, f64, f64)> = vec![
        ("SGLang", 0.0, 0.0, 0.0),
        ("vLLM", 0.0, 0.0, 0.0),
        ("llama.cpp", 0.0, 0.0, 0.0),
    ];
    for model in ModelKind::ALL {
        for gpu in GpuKind::ALL {
            for n in 3..=6 {
                let find = |p: &str| {
                    cells
                        .iter()
                        .find(|(m, g, nn, o)| {
                            *m == model && *g == gpu && *nn == n && o.policy_name == p
                        })
                        .map(|(_, _, _, o)| o)
                };
                let Some(ours) = find("AgentServe") else { continue };
                for entry in best.iter_mut() {
                    let Some(b) = find(entry.0) else { continue };
                    entry.1 = entry.1.max(b.report.ttft.p95 / ours.report.ttft.p95.max(1e-9));
                    entry.2 = entry.2.max(b.report.tpot.p95 / ours.report.tpot.p95.max(1e-9));
                    entry.3 = entry
                        .3
                        .max(ours.report.throughput_tok_s / b.report.throughput_tok_s.max(1e-9));
                }
            }
        }
    }
    println!("\nHeadline improvement ratios (best across grid, p95):");
    for (k, t, p, thr) in &best {
        println!("  vs {k:<10}  TTFT {t:.1}x   TPOT {p:.1}x   throughput {thr:.1}x");
    }
    println!("(paper: TTFT up to 2.8x vs llama.cpp, 1.5-1.8x vs vLLM, 1.1-1.3x vs SGLang; TPOT up to 2.7x)");
}

/// Fig. 6: session-level joint SLO attainment across the grid.
pub fn fig6_slo_attainment(json_dir: Option<&str>) -> crate::Result<()> {
    println!("\n=== Figure 6: session-level SLO attainment ===");
    let cells = run_grid();
    let mut rows = Vec::new();
    for model in ModelKind::ALL {
        for gpu in GpuKind::ALL {
            println!("\n--- {model} on {gpu} ---");
            print!("{:<11}", "policy");
            for n in 3..=6 {
                print!(" N={n:<6}");
            }
            println!();
            for policy in Policy::paper_lineup() {
                print!("{:<11}", policy.name());
                for n in 3..=6 {
                    if let Some((_, _, _, out)) = cells.iter().find(|(m, g, nn, o)| {
                        *m == model && *g == gpu && *nn == n && o.policy_name == policy.name()
                    }) {
                        print!(" {:>5.1}% ", out.slo.rate() * 100.0);
                        rows.push(Value::obj(vec![
                            ("model", model.name().into()),
                            ("gpu", gpu.name().into()),
                            ("agents", n.into()),
                            ("policy", policy.name().into()),
                            ("slo_rate", out.slo.rate().into()),
                        ]));
                    }
                }
                println!();
            }
        }
    }
    println!("(paper: AgentServe highest everywhere; near-perfect on 5090; baselines drop past N=4 on A5000)");
    dump_json(json_dir, "fig6", &Value::Arr(rows))
}

/// Fig. 7: ablation — Full vs No-Alg vs No-Green, N=4, p95 TTFT/TPOT.
pub fn fig7_ablation(json_dir: Option<&str>) -> crate::Result<()> {
    println!("\n=== Figure 7: ablation (N=4, p95) ===");
    let mut rows = Vec::new();
    for model in ModelKind::ALL {
        for gpu in GpuKind::ALL {
            println!("\n--- {model} on {gpu} ---");
            println!("{:<11} {:>10} {:>10}", "variant", "TTFT p95", "TPOT p95");
            for policy in Policy::ablation_lineup() {
                let cfg = Config::preset(model, gpu);
                let params = SimParams {
                    n_agents: 4,
                    sessions_per_agent: 2,
                    workload: WorkloadKind::ReAct,
                    ..SimParams::default()
                };
                let out = run_sim(&cfg, policy, &params);
                println!(
                    "{:<11} {:>8.0}ms {:>8.1}ms",
                    out.policy_name, out.report.ttft.p95, out.report.tpot.p95
                );
                rows.push(Value::obj(vec![
                    ("model", model.name().into()),
                    ("gpu", gpu.name().into()),
                    ("variant", out.policy_name.as_str().into()),
                    ("ttft_p95", out.report.ttft.p95.into()),
                    ("tpot_p95", out.report.tpot.p95.into()),
                ]));
            }
        }
    }
    println!("(paper: No-Alg +15-25% TTFT, up to 1.4x TPOT p95; No-Green adds 20-30% TPOT variance)");
    dump_json(json_dir, "fig7", &Value::Arr(rows))
}

/// Table I: token distribution across workloads and models.
pub fn table1_token_distribution(json_dir: Option<&str>) -> crate::Result<()> {
    println!("\n=== Table I: token distribution across workloads and models ===");
    println!(
        "{:<6} {:<15} {:<18} {:<18} {:<18}",
        "", "stage", ModelKind::Qwen3B, ModelKind::Qwen7B, ModelKind::Llama8B
    );
    let mut rows = Vec::new();
    for kind in WorkloadKind::ALL {
        let stats: Vec<TokenStats> = ModelKind::ALL
            .iter()
            .map(|&m| {
                let mut gen = WorkloadGenerator::new(kind, m, 11);
                TokenStats::from_sessions(&gen.sessions(300))
            })
            .collect();
        let tag = match kind {
            WorkloadKind::ReAct => "ReAct",
            WorkloadKind::PlanAndExecute => "P&E",
        };
        println!(
            "{:<6} {:<15} {:<18} {:<18} {:<18}",
            tag,
            "Cold Prefill",
            stats[0].cold_prefill.to_string(),
            stats[1].cold_prefill.to_string(),
            stats[2].cold_prefill.to_string()
        );
        println!(
            "{:<6} {:<15} {:<18} {:<18} {:<18}",
            "",
            "Resume Prefill",
            stats[0].resume_prefill.to_string(),
            stats[1].resume_prefill.to_string(),
            stats[2].resume_prefill.to_string()
        );
        println!(
            "{:<6} {:<15} {:<18} {:<18} {:<18}",
            "",
            "Decode",
            stats[0].decode.to_string(),
            stats[1].decode.to_string(),
            stats[2].decode.to_string()
        );
        for (m, s) in ModelKind::ALL.iter().zip(&stats) {
            rows.push(Value::obj(vec![
                ("workload", tag.into()),
                ("model", m.name().into()),
                ("cold", dist_value(&s.cold_prefill)),
                ("resume", dist_value(&s.resume_prefill)),
                ("decode", dist_value(&s.decode)),
            ]));
        }
    }
    println!("(paper: cold 2.5k-3.5k; ReAct resume 30-127(56); P&E resume 125-421(251); short decodes)");
    dump_json(json_dir, "table1", &Value::Arr(rows))
}

/// Theorem 1 / Corollary 2 evaluated on the profiled curves, plus the
/// measured prefill-retention of an actual AgentServe run.
pub fn analyze_competitive(
    model: ModelKind,
    gpu: GpuKind,
    delta: u32,
    eps: f64,
) -> crate::Result<()> {
    let cfg = Config::preset(model, gpu);
    let cost = CostModel::new(&cfg.model, &cfg.gpu);
    let pool =
        GreenContextPool::new(cfg.gpu.sm_count, cfg.engine.green_slots, cfg.engine.rebind_us);
    let analyzer = CompetitiveAnalyzer::new(cost, pool.slot_sizes().to_vec(), cfg.gpu.sm_count);

    println!("\n=== Competitive-ratio analysis ({model} on {gpu}) ===");
    println!(
        "decode SLO: TPOT <= {:.1} ms  =>  r_min = {:.1} tok/s",
        cfg.slo.tpot_ms,
        cfg.slo.r_min_tokens_per_s()
    );
    for eta in [0.25, 0.5, 0.75] {
        match analyzer.bound(&cfg.slo, delta, eps, eta) {
            Some(b) => println!(
                "eta_cold={eta:.2}: R*_g={} SMs, rho >= {:.3} (linearized {:.3}); mu_P opt {:.0} vs ours {:.0} tok/s",
                b.r_star_g, b.rho_bound, b.rho_linearized, b.mu_p_opt, b.mu_p_ours
            ),
            None => println!("eta_cold={eta:.2}: decode SLO infeasible at full GPU"),
        }
    }

    // Measured retention from an actual simulated run.
    let params = SimParams { n_agents: 4, sessions_per_agent: 2, ..SimParams::default() };
    let out = run_sim(&cfg, Policy::AgentServe(Default::default()), &params);
    if let Some(rho) = analyzer.measured_rho(&cfg.slo, out.report.prefill_tok_s, out.eta_cold) {
        println!(
            "measured: prefill {:.0} tok/s at eta_cold={:.2}  =>  retention rho = {:.3}",
            out.report.prefill_tok_s, out.eta_cold, rho
        );
        println!("(rho is vs. a *continuously busy* offline prefill optimum; idle tool-wait time lowers it)");
    }
    Ok(())
}
