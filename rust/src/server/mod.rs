//! CLI front end: `agentserve bench|figures|analyze|serve`.
//!
//! [`figures`] is the benchmark harness of deliverable (d): one function per
//! paper table/figure, printing the same rows/series the paper reports and
//! optionally dumping JSON for plotting.

pub mod figures;

use crate::config::{Config, GpuKind, ModelKind};
use crate::engine::{Policy, SimParams};
use crate::util::cli::Args;
use crate::workload::WorkloadKind;

pub const USAGE: &str = "\
agentserve — efficient agentic AI serving on a consumer-grade GPU (reproduction)

USAGE:
  agentserve bench   [--policy P] [--model M] [--gpu G] [--agents N]
                     [--sessions K] [--workload react|pe] [--seed S]
                     [--config file.json] [--save-trace t.json]
                     [--replay-trace t.json]
  agentserve figures [--fig 2|3|5|6|7] [--table 1] [--all] [--json-dir DIR]
  agentserve analyze [--model M] [--gpu G] [--delta D] [--eps E]
  agentserve serve   [--artifacts DIR] [--agents N] [--policy agentserve|fcfs]
                     [--tool-scale F]

policies: agentserve | no-alg | no-green | sglang | vllm | llamacpp
models:   3b | 7b | 8b (cost-model) / tiny (real engine)
gpus:     a5000 | 5090
";

/// Entry point used by `main` (and by CLI tests).
pub fn run(args: Args) -> crate::Result<()> {
    match args.subcommand.as_deref() {
        Some("bench") => bench(&args),
        Some("figures") => run_figures(&args),
        Some("analyze") => {
            let model: ModelKind = args.get_or("model", "7b").parse()?;
            let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
            let delta = args.get_u32("delta", 7)?;
            let eps = args.get_f64("eps", 0.01)?;
            figures::analyze_competitive(model, gpu, delta, eps)
        }
        Some("serve") => serve_real(&args),
        Some(other) => {
            eprintln!("{USAGE}");
            anyhow::bail!("unknown subcommand '{other}'")
        }
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn bench(args: &Args) -> crate::Result<()> {
    let model: ModelKind = args.get_or("model", "7b").parse()?;
    let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
    let cfg = match args.get("config") {
        Some(p) => Config::from_path(p)?,
        None => Config::preset(model, gpu),
    };
    let policy: Policy = args.get_or("policy", "agentserve").parse()?;
    let params = SimParams {
        n_agents: args.get_usize("agents", 4)?,
        sessions_per_agent: args.get_usize("sessions", 3)?,
        workload: args.get_or("workload", "react").parse::<WorkloadKind>()?,
        seed: args.get_u64("seed", 7)?,
        ..SimParams::default()
    };
    // Trace record/replay for paired comparisons and regression debugging.
    let out = if let Some(path) = args.get("replay-trace") {
        let trace = crate::workload::Trace::load(path)?;
        let scripts = trace.events.into_iter().map(|e| e.script).collect();
        crate::engine::sim::run_sim_scripts(&cfg, policy, &params, scripts)
    } else {
        let mut gen = crate::workload::WorkloadGenerator::new(
            params.workload,
            cfg.model.kind,
            params.seed,
        );
        let scripts = gen.sessions(params.n_agents * params.sessions_per_agent);
        if let Some(path) = args.get("save-trace") {
            let trace =
                crate::workload::Trace::concurrent(scripts.clone(), params.n_agents, params.stagger_us);
            trace.save(path)?;
            println!("trace saved to {path}");
        }
        crate::engine::sim::run_sim_scripts(&cfg, policy, &params, scripts)
    };
    println!(
        "== {} | {} | {} | {} agents ==",
        out.policy_name, model, gpu, params.n_agents
    );
    println!("{}", out.report);
    println!(
        "  SLO   {}/{} attained ({:.1}%)",
        out.slo.attained,
        out.slo.sessions,
        out.slo.rate() * 100.0
    );
    println!(
        "  mix   eta_cold={:.2} cold_routed={} merged={} rerouted={} rebinds={}",
        out.eta_cold, out.cold_routed, out.resume_merged, out.resume_rerouted, out.rebinds.rebinds
    );
    Ok(())
}

fn run_figures(args: &Args) -> crate::Result<()> {
    let all = args.has("all");
    let fig = args.get("fig").map(|f| f.parse::<u32>()).transpose()?;
    let table = args.get("table").map(|t| t.parse::<u32>()).transpose()?;
    let jd = args.get("json-dir");
    if all || fig == Some(2) {
        figures::fig2_tpot_timeline(jd)?;
    }
    if all || fig == Some(3) {
        figures::fig3_sm_curves(jd)?;
    }
    if all || fig == Some(5) {
        figures::fig5_latency_throughput(jd)?;
    }
    if all || fig == Some(6) {
        figures::fig6_slo_attainment(jd)?;
    }
    if all || fig == Some(7) {
        figures::fig7_ablation(jd)?;
    }
    if all || table == Some(1) {
        figures::table1_token_distribution(jd)?;
    }
    if !all && fig.is_none() && table.is_none() {
        anyhow::bail!("pass --fig N, --table N, or --all");
    }
    Ok(())
}

/// End-to-end demo on the real PJRT engine.
fn serve_real(args: &Args) -> crate::Result<()> {
    use crate::engine::real::{run_real, RealPolicy};
    use crate::workload::WorkloadGenerator;

    let artifacts = args.get_or("artifacts", "artifacts");
    let policy = match args.get_or("policy", "agentserve").to_ascii_lowercase().as_str() {
        "agentserve" => RealPolicy::AgentServe,
        "fcfs" | "fcfs-mixed" | "llamacpp" => RealPolicy::FcfsMixed,
        other => anyhow::bail!("unknown real policy: {other} (agentserve|fcfs)"),
    };
    let tool_scale = args.get_f64("tool-scale", 0.1)?;
    let mut engine = crate::runtime::PjrtEngine::load(artifacts)?;
    let n = args
        .get_usize("agents", 4)?
        .min(engine.geometry().decode_batch);
    let mut gen = WorkloadGenerator::new(WorkloadKind::ReAct, ModelKind::Tiny, 7);
    let scripts = gen.sessions(n);
    println!(
        "serving {n} concurrent ReAct sessions on the real engine ({} params)…",
        engine.geometry().param_count
    );
    let out = run_real(
        &mut engine,
        policy,
        scripts,
        crate::config::SchedulerConfig::calibrated(10.0),
        tool_scale,
    )?;
    println!("== {} (real PJRT compute) ==", out.policy);
    println!("{}", out.report);
    println!(
        "  engine: {} prefill calls ({} ms), {} decode calls ({} ms), {:.1} MB cache traffic",
        out.engine_stats.prefill_calls,
        out.engine_stats.prefill_us / 1000,
        out.engine_stats.decode_calls,
        out.engine_stats.decode_us / 1000,
        out.engine_stats.cache_roundtrip_bytes as f64 / 1e6
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn bench_subcommand_runs() {
        run(args("bench --model 3b --agents 3 --sessions 1")).unwrap();
    }

    #[test]
    fn unknown_subcommand_fails() {
        assert!(run(args("frobnicate")).is_err());
    }

    #[test]
    fn figures_requires_selection() {
        assert!(run(args("figures")).is_err());
    }

    #[test]
    fn analyze_runs() {
        run(args("analyze --model 7b --gpu 5090")).unwrap();
    }

    #[test]
    fn trace_record_then_replay_matches() {
        let dir = std::env::temp_dir().join("agentserve_cli_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        let p = p.to_str().unwrap();
        run(args(&format!(
            "bench --model 3b --agents 3 --sessions 1 --save-trace {p}"
        )))
        .unwrap();
        run(args(&format!(
            "bench --model 3b --agents 3 --sessions 1 --replay-trace {p} --policy vllm"
        )))
        .unwrap();
    }
}
