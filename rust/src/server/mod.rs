//! CLI front end: `agentserve bench|scenario|figures|analyze|serve`.
//!
//! [`figures`] is the benchmark harness of deliverable (d): one function per
//! paper table/figure, printing the same rows/series the paper reports and
//! optionally dumping JSON for plotting.

pub mod figures;

use crate::config::{Config, GpuKind, ModelKind};
use crate::engine::{Policy, SimParams};
use crate::util::cli::Args;
use crate::workload::WorkloadKind;

pub const USAGE: &str = "\
agentserve — efficient agentic AI serving on a consumer-grade GPU (reproduction)

USAGE:
  agentserve bench    [--policy P] [--model M] [--gpu G] [--agents N]
                      [--sessions K] [--workload react|pe] [--seed S]
                      [--config file.json] [--save-trace t.json]
                      [--replay-trace t.json]
  agentserve scenario list
  agentserve scenario run    (--name S | --file f.json) [--policy P | --all-policies]
                             [--model M] [--gpu G] [--seed N]
                             [--exec-out out.jsonl | --events out.jsonl]
                             [--trace-out t.json] [--probe-out p.json|p.csv
                              [--probe-interval-us US]]
                             [--kv-blocks N] [--kv-block-size N] [--prefix-sharing]
                             [--cpu-workers N [--tool-dist D]]
  agentserve scenario record (--name S | --file f.json) --out trace.jsonl
                             [--policy P] [--model M] [--gpu G] [--seed N]
                             [--kv-blocks N] [--kv-block-size N] [--prefix-sharing]
  agentserve scenario replay --trace trace.jsonl [--policy P | --all-policies]
                             [--model M] [--gpu G] [--verify]
                             [--kv-blocks N] [--kv-block-size N] [--prefix-sharing]
  agentserve scenario sweep  (--name SWEEP | (--scenario S | --file f.json)
                              (--rates r1,r2,… | --agents n1,n2,… | --mix f1,f2,…
                               | --kv-blocks b1,b2,… | --fan-outs d1,d2,…
                               | --cpu-workers c1,c2,…))
                             [--policy P] [--model M] [--gpu G] [--seed N]
                             [--threads T] [--out report.json] [--csv report.csv]
  agentserve experiment run  --file manifest.json [--model M] [--gpu G]
                             [--seed N] [--threads T]
                             [--out report.json] [--csv report.csv]
  agentserve experiment example
  agentserve bench suite     [--policy P] [--model M] [--gpu G] [--seed N]
                             [--threads T] [--label L] [--out BENCH.json]
  agentserve bench diff      BASELINE.json NEW.json [--tolerance F]
                             [--metric-tolerance F]
  agentserve workflow list
  agentserve workflow run    --name W [--policy P | --all-policies] [--tasks N]
                             [--rate R] [--fan-out D] [--task-slo-ms MS]
                             [--fail-prob P] [--model M] [--gpu G] [--seed N]
                             [--exec-out out.jsonl]
                             [--trace-out t.json] [--probe-out p.json|p.csv
                              [--probe-interval-us US]]
                             [--kv-blocks N] [--kv-block-size N] [--prefix-sharing]
                             [--cpu-workers N [--tool-dist D]]
  agentserve cluster list
  agentserve cluster run     (--name S | --file f.json) [--replicas N] [--router R]
                             [--policy P | --all-policies] [--model M] [--gpu G]
                             [--seed N] [--per-replica]
                             [--exec-out out.jsonl | --events out.jsonl]
                             [--trace-out t.json] [--probe-out p.json|p.csv
                              [--probe-interval-us US]]
                             [--autoscale [--min-replicas N] [--max-replicas M]]
                             [--fail-rate R [--restart-ms MS]]
                             [--kv-blocks N] [--kv-block-size N] [--prefix-sharing]
                             [--cpu-workers N [--tool-dist D]]
  agentserve cluster sweep   (--name SWEEP | (--scenario S | --file f.json)
                              (--replica-counts n1,n2,… | --chaos r1,r2,…))
                             [--router R] [--replicas N] [--policy P]
                             [--model M] [--gpu G] [--seed N] [--threads T]
                             [--out report.json] [--csv report.csv]
  agentserve probe    (--name S | --file f.json) [--interval-us US]
                      [--replicas N [--router R]] [--policy P] [--model M]
                      [--gpu G] [--seed N] [--out p.json|p.csv]
  agentserve trace validate  --file trace.json
  agentserve figures  [--fig 2|3|5|6|7] [--table 1] [--all] [--json-dir DIR]
  agentserve analyze  [--model M] [--gpu G] [--delta D] [--eps E]
  agentserve serve    [--artifacts DIR] [--agents N] [--policy agentserve|fcfs]
                      [--tool-scale F]

policies:  agentserve | no-alg | no-green | sglang | vllm | llamacpp
models:    3b | 7b | 8b (cost-model) / tiny (real engine)
gpus:      a5000 | 5090
scenarios: paper-fig5 | burst-storm | mixed-fleet | long-tool | open-loop-sweep
           | memory-pressure | shared-prefix-fleet | failure-storm
           | diurnal-burst | tool-storm | slow-sandbox
sweeps:    paper-fig5-sweep | agent-scaling | mix-shift | kv-knee | fanout-knee
           | cpu-knee | gpus-for-slo | chaos-resilience | autoscale-frontier
           (sweep runs all paper policies unless --policy is given; see
           rust/src/workload/README.md for the scenario/sweep file schema)
routers:   round-robin | least-outstanding | session-affinity | cache-aware
           — fleet session routing for `cluster run|sweep` (--replicas N
           single-GPU replicas behind the router; gpus-for-slo reports the
           smallest fleet meeting the TTFT SLO — the inverse knee)
workflows: single-react | plan-execute | supervisor-worker | pipeline-chain
           | debate — multi-agent DAG tasks (fan-out, join barriers, context
           continuations) with task-level makespan/SLO metrics
kv:        --kv-blocks bounds the KV pool (0 = unbounded), --kv-block-size
           sets the page size, --prefix-sharing enables cross-session
           system-prompt reuse; on `scenario sweep`, --kv-blocks is the
           memory sweep axis instead
host:      --cpu-workers N bounds each replica's tool sandbox at N CPU
           workers (0 = unbounded legacy host — tool calls return after
           their scripted latency with no queueing); --tool-dist shapes the
           seeded service-time draw: fixed | uniform:LO,HI |
           lognormal:MU,SIGMA (multipliers on the scripted latency). On
           `scenario sweep`, --cpu-workers c1,c2,… is the host capacity
           axis instead; the cpu-knee registry sweep reports the smallest
           worker count meeting the task SLO
chaos:     `cluster run --fail-rate R` seeds replica crashes at R
           crashes/replica/min (0 = off; --restart-ms sets the cold-restart
           latency); `cluster sweep --chaos r1,r2,…` sweeps that rate on a
           fixed --replicas fleet; `workflow run --fail-prob P` makes every
           tool node fail each attempt with probability P (3 attempts,
           exponential backoff). All fault schedules are seeded and
           deterministic: reruns are byte-identical
threads:   sweep/experiment grids fan out over a worker pool; --threads T
           (or AGENTSERVE_SWEEP_THREADS) sets the width, default = available
           cores, 1 = the serial loop. Reports are byte-identical at any
           width — parallelism changes wall-clock only
experiment: a JSON manifest crossing rate × replicas × kv-blocks × fan-out
           × cpu-workers into one grid with per-cell overrides and pinned
           seeds;
           `experiment example` prints a ready-to-edit manifest (schema in
           rust/src/workload/README.md)
bench gate: `bench suite` times every registry sweep through the shared
           sampling path and writes a BENCH_*.json artifact; `bench diff`
           compares two artifacts and exits non-zero on wall-clock or
           SLO-metric regressions beyond tolerance (the CI perf gate;
           --tolerance 0.5 wall slack, --metric-tolerance 0 exact)
autoscale: `cluster run --autoscale` hands the fleet to a deterministic
           control loop scaling between --min-replicas (default 1) and
           --max-replicas (default 4) on the virtual clock: EWMA-smoothed
           pressure, hysteresis, cold boots up, drains down. Conflicts
           with --replicas (the controller owns the size, starting at the
           band floor). `cluster sweep --name autoscale-frontier` maps the
           cost-vs-SLO frontier (up-thresh 0 = static provisioned-for-peak
           baseline; every row carries the replica_us GPU-time integral)
telemetry: --trace-out writes per-session span trees (queue wait, cold/
           resume prefill, decode, kv-stall, tool-wait, preemption) as
           Chrome trace-event JSON — load it in chrome://tracing or
           Perfetto (pid = replica, tid = session); the GPU-time
           attribution report (phase_report) rides inside the same file.
           --probe-out samples queue depths, decode-batch occupancy, KV
           usage, host backlog, and the control knobs on a fixed
           virtual-time grid (--probe-interval-us, default 50000) as
           pretty JSON, or CSV when the path ends in .csv. `agentserve
           probe` is the standalone sampler; `agentserve trace validate`
           checks a trace artifact. Telemetry is off by default, never
           perturbs the simulation (reports stay byte-identical with it
           on or off), and reruns are byte-identical
";

/// Entry point used by `main` (and by CLI tests).
pub fn run(args: Args) -> crate::Result<()> {
    // Default-deny the action positional: only the grouped subcommands
    // take one, so a stray positional on any other (or future) subcommand
    // errors loudly instead of being silently ignored.
    if !matches!(
        args.subcommand.as_deref(),
        Some("scenario")
            | Some("workflow")
            | Some("cluster")
            | Some("experiment")
            | Some("bench")
            | Some("trace")
    ) {
        if let Some(a) = &args.action {
            anyhow::bail!("unexpected positional argument '{a}'");
        }
    }
    // Operand positionals are rarer still: only `bench diff` takes them.
    if !(args.subcommand.as_deref() == Some("bench") && args.action.as_deref() == Some("diff")) {
        if let Some(stray) = args.rest().first() {
            anyhow::bail!("unexpected positional argument '{stray}'");
        }
    }
    match args.subcommand.as_deref() {
        Some("bench") => match args.action.as_deref() {
            None => bench(&args),
            Some("suite") => bench_suite(&args),
            Some("diff") => bench_diff(&args),
            Some(a) => {
                eprintln!("{USAGE}");
                anyhow::bail!("unknown bench action '{a}'")
            }
        },
        Some("experiment") => experiment_cmd(&args),
        Some("scenario") => scenario_cmd(&args),
        Some("workflow") => workflow_cmd(&args),
        Some("cluster") => cluster_cmd(&args),
        Some("probe") => probe_cmd(&args),
        Some("trace") => trace_cmd(&args),
        Some("figures") => run_figures(&args),
        Some("analyze") => {
            let model: ModelKind = args.get_or("model", "7b").parse()?;
            let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
            let delta = args.get_u32("delta", 7)?;
            let eps = args.get_f64("eps", 0.01)?;
            figures::analyze_competitive(model, gpu, delta, eps)
        }
        Some("serve") => serve_real(&args),
        Some(other) => {
            eprintln!("{USAGE}");
            anyhow::bail!("unknown subcommand '{other}'")
        }
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn bench(args: &Args) -> crate::Result<()> {
    let model: ModelKind = args.get_or("model", "7b").parse()?;
    let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
    let cfg = match args.get("config") {
        Some(p) => Config::from_path(p)?,
        None => Config::preset(model, gpu),
    };
    let policy: Policy = args.get_or("policy", "agentserve").parse()?;
    let params = SimParams {
        n_agents: args.get_usize("agents", 4)?,
        sessions_per_agent: args.get_usize("sessions", 3)?,
        workload: args.get_or("workload", "react").parse::<WorkloadKind>()?,
        seed: args.get_u64("seed", 7)?,
        ..SimParams::default()
    };
    // Trace record/replay for paired comparisons and regression debugging.
    let out = if let Some(path) = args.get("replay-trace") {
        let trace = load_trace_any(path)?;
        crate::engine::run_sim_trace(&cfg, policy, &trace)
    } else {
        let mut gen = crate::workload::WorkloadGenerator::new(
            params.workload,
            cfg.model.kind,
            params.seed,
        );
        let scripts = gen.sessions(params.n_agents * params.sessions_per_agent);
        let save = args.get("save-trace");
        let scripts_for_trace = save.map(|_| scripts.clone());
        let out = crate::engine::sim::run_sim_scripts(&cfg, policy, &params, scripts);
        if let Some(path) = save {
            // Save *realized* arrivals (wave > 0 sessions at the times they
            // actually chained in), so the trace replays this run faithfully.
            let trace = crate::workload::Trace::with_arrivals(
                scripts_for_trace.expect("cloned when saving"),
                &out.arrivals_us,
            );
            trace.save(path)?;
            println!("trace saved to {path}");
        }
        out
    };
    println!(
        "== {} | {} | {} | {} agents ==",
        out.policy_name, model, gpu, params.n_agents
    );
    println!("{}", out.report);
    println!(
        "  SLO   {}/{} attained ({:.1}%)",
        out.slo.attained,
        out.slo.sessions,
        out.slo.rate() * 100.0
    );
    println!(
        "  mix   eta_cold={:.2} cold_routed={} merged={} rerouted={} rebinds={}",
        out.eta_cold, out.cold_routed, out.resume_merged, out.resume_rerouted, out.rebinds.rebinds
    );
    if let Some(kv) = &out.kv {
        println!("  mem   {kv}");
    }
    Ok(())
}

/// Load a workload trace in either format (pretty JSON from `--save-trace`,
/// or the scenario engine's JSONL interchange). A whole-file JSON document
/// carrying an `"events"` key is the pretty format — its schema errors are
/// reported as such, not masked as bogus JSONL line errors; everything else
/// (including single-line traces) goes through the JSONL parser.
fn load_trace_any(path: &str) -> crate::Result<crate::workload::Trace> {
    let text = std::fs::read_to_string(path)?;
    // Execution-event logs are schema-tagged on every line precisely so
    // they can't be mistaken for a workload trace (both are JSONL).
    let exec_tag = format!("\"schema\":\"{}\"", crate::engine::EXEC_SCHEMA);
    if text.lines().next().is_some_and(|l| l.contains(&exec_tag)) {
        anyhow::bail!(
            "'{path}' is an execution-event log ({}), not a workload trace — \
             record a replayable trace with `agentserve scenario record`",
            crate::engine::EXEC_SCHEMA
        );
    }
    if let Ok(v) = crate::util::json::parse(&text) {
        if v.get("events").is_some() {
            return crate::workload::Trace::from_value(&v);
        }
    }
    crate::workload::Trace::from_jsonl(&text)
}

/// Load a scenario file from disk, applying its optional embedded sparse
/// `"config"` overrides on top of the CLI's model/gpu preset. Shared by
/// `scenario run|record` (`--file`) and `scenario sweep` base resolution.
fn scenario_from_file(path: &str, cfg: &mut Config) -> crate::Result<crate::workload::Scenario> {
    let v = crate::util::json::parse(&std::fs::read_to_string(path)?)?;
    let sc = crate::workload::Scenario::from_value(&v)?;
    if let Some(overrides) = v.get("config") {
        cfg.apply_overrides(overrides)?;
        cfg.validate()?;
    }
    Ok(sc)
}

/// Resolve the scenario named on the command line: `--name` from the
/// built-in registry, or `--file` from disk (which may embed sparse
/// `"config"` overrides applied on top of the CLI's model/gpu preset).
fn load_scenario_arg(args: &Args, cfg: &mut Config) -> crate::Result<crate::workload::Scenario> {
    use crate::workload::Scenario;
    if let Some(path) = args.get("file") {
        scenario_from_file(path, cfg)
    } else if let Some(name) = args.get("name") {
        Scenario::by_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario '{name}' (try `agentserve scenario list`)")
        })
    } else {
        anyhow::bail!("pass --name <scenario> or --file <scenario.json>")
    }
}

/// Resolve the worker-pool width for a grid run: `--threads` beats
/// `AGENTSERVE_SWEEP_THREADS` beats available parallelism. Reports are
/// byte-identical at any width, so this only changes wall-clock.
fn grid_threads_arg(args: &Args) -> crate::Result<usize> {
    let cli = match args.get("threads") {
        Some(t) => Some(
            t.parse::<usize>()
                .map_err(|e| anyhow::anyhow!("--threads must be a positive integer: {e}"))?,
        ),
        None => None,
    };
    crate::util::pool::grid_threads(cli)
}

fn scenario_policies(args: &Args) -> crate::Result<Vec<Policy>> {
    if args.has("all-policies") {
        Ok(Policy::paper_lineup())
    } else {
        Ok(vec![args.get_or("policy", "agentserve").parse()?])
    }
}

fn print_scenario_outcome(out: &crate::engine::SimOutcome) {
    println!("--- {} ---", out.policy_name);
    println!("{}", out.report);
    println!(
        "  SLO   {}/{} attained ({:.1}%)",
        out.slo.attained,
        out.slo.sessions,
        out.slo.rate() * 100.0
    );
    // Memory line only on the paged path, so default-config output stays
    // byte-identical to the pre-memory-model CLI; likewise the task line
    // appears only for workflow DAG scenarios.
    if let Some(kv) = &out.kv {
        println!("  mem   {kv}");
    }
    if let Some(wf) = &out.workflow {
        println!("  task  {wf}");
    }
    if let Some(h) = &out.host {
        println!("  host  {h}");
    }
}

/// Apply the `--kv-blocks` / `--kv-block-size` / `--prefix-sharing` CLI
/// overrides onto the config. Returns whether any flag was present — when
/// the user constrains KV explicitly, scenario-embedded `kv` blocks are
/// dropped so the CLI wins (flags merge onto the scenario's own settings).
fn apply_kv_flags(
    args: &Args,
    cfg: &mut Config,
    scenario_kv: Option<crate::config::KvConfig>,
) -> crate::Result<bool> {
    let present = args.get("kv-blocks").is_some()
        || args.get("kv-block-size").is_some()
        || args.has("prefix-sharing");
    if !present {
        return Ok(false);
    }
    let mut kv = scenario_kv.unwrap_or(cfg.kv);
    kv.num_blocks = args.get_usize("kv-blocks", kv.num_blocks)?;
    kv.block_size = args.get_usize("kv-block-size", kv.block_size)?;
    if args.has("prefix-sharing") {
        kv.prefix_sharing = true;
    }
    cfg.kv = kv;
    cfg.validate()?;
    Ok(true)
}

/// Apply the `--cpu-workers` / `--tool-dist` host-execution CLI overrides
/// onto the config. Returns whether any flag was present — when the user
/// constrains the host explicitly, scenario-embedded `host` blocks are
/// dropped so the CLI wins (flags merge onto the scenario's own settings).
/// `--cpu-workers 0` is the explicit legacy host (unbounded, no queueing):
/// it strips an active scenario host and is byte-identical to no flag at
/// all on host-less scenarios.
fn apply_host_flags(
    args: &Args,
    cfg: &mut Config,
    scenario_host: Option<crate::config::HostConfig>,
) -> crate::Result<bool> {
    let present = args.get("cpu-workers").is_some() || args.get("tool-dist").is_some();
    if !present {
        return Ok(false);
    }
    let mut host = scenario_host.unwrap_or_else(|| cfg.host.clone());
    host.cpu_workers = args.get_usize("cpu-workers", host.cpu_workers)?;
    if let Some(d) = args.get("tool-dist") {
        host.latency = d.parse()?;
    }
    // Loud refusal over silent drop: a latency shape on an inactive host
    // model would otherwise do nothing.
    anyhow::ensure!(
        host.is_active() || args.get("tool-dist").is_none(),
        "--tool-dist shapes the host tool-service distribution; pass --cpu-workers N \
         (N >= 1) or a host-carrying scenario (e.g. tool-storm) to enable the host model"
    );
    cfg.host = host;
    cfg.validate()?;
    Ok(true)
}

/// Apply the `--trace-out` / `--probe-out` telemetry CLI flags onto the
/// scenario: they activate the obs layer (span tracing / time-series
/// probes) on top of whatever `obs` block the scenario file already
/// carries, and name the artifact paths. `--probe-interval-us` tunes the
/// sampling grid (default 50 ms of virtual time). Returns the two
/// artifact paths; both `None` leaves the scenario untouched.
fn apply_obs_flags(
    args: &Args,
    scenario: &mut crate::workload::Scenario,
) -> crate::Result<(Option<String>, Option<String>)> {
    let trace_out = args.get("trace-out").map(String::from);
    let probe_out = args.get("probe-out").map(String::from);
    // Loud refusal over silent drop: an interval with no probe artifact
    // to write would otherwise do nothing.
    anyhow::ensure!(
        probe_out.is_some() || args.get("probe-interval-us").is_none(),
        "--probe-interval-us tunes the --probe-out sampling grid; pass \
         --probe-out <file> to record the time series"
    );
    if trace_out.is_none() && probe_out.is_none() {
        return Ok((None, None));
    }
    let mut obs = scenario.obs.unwrap_or_default();
    if trace_out.is_some() {
        obs.trace = true;
    }
    if probe_out.is_some() {
        obs.probe.interval_us = args.get_u64("probe-interval-us", 50_000)?;
    }
    obs.validate()?;
    scenario.obs = Some(obs);
    Ok((trace_out, probe_out))
}

/// Loudly refuse the per-run capture flags (`--trace-out`, `--probe-out`,
/// `--exec-out`) on actions that run many simulations or none at all —
/// a silently dropped flag would hide the user's intent.
fn refuse_capture_flags(args: &Args, ctx: &str) -> crate::Result<()> {
    for flag in ["trace-out", "probe-out", "probe-interval-us", "exec-out", "events"] {
        anyhow::ensure!(
            args.get(flag).is_none(),
            "--{flag} captures a single run's telemetry; {ctx}"
        );
    }
    Ok(())
}

/// Write the telemetry artifacts of one traced/probed run: the Chrome
/// trace-event JSON (with the GPU-time attribution report riding inside,
/// so stdout stays byte-identical to an untraced run) and/or the probe
/// time series (CSV when the path ends in `.csv`, pretty JSON otherwise).
/// Confirmations go to stderr for the same reason. `slug` splices a
/// per-policy tag into the filename on `--all-policies` runs.
fn save_obs_artifacts(
    trace_base: Option<&str>,
    probe_base: Option<&str>,
    slug: Option<&str>,
    obs: Option<&crate::obs::ObsLog>,
    phases: Option<&crate::obs::PhaseReport>,
) -> crate::Result<()> {
    let resolve = |base: &str| match slug {
        Some(s) => events_path(base, s),
        None => base.to_string(),
    };
    if let Some(base) = trace_base {
        let log = obs.ok_or_else(|| {
            anyhow::anyhow!("--trace-out was set but the run kept no span log (bug)")
        })?;
        let path = resolve(base);
        std::fs::write(&path, log.to_chrome_trace(phases).to_string_pretty())?;
        eprintln!("  {} spans + {} instants -> {path}", log.spans.len(), log.instants.len());
    }
    if let Some(base) = probe_base {
        let probes = obs.and_then(|l| l.probes.as_ref()).ok_or_else(|| {
            anyhow::anyhow!("--probe-out was set but the run kept no samples (bug)")
        })?;
        let path = resolve(base);
        if path.ends_with(".csv") {
            std::fs::write(&path, probes.to_csv())?;
        } else {
            std::fs::write(&path, probes.to_value().to_string_pretty())?;
        }
        eprintln!(
            "  {} probe samples ({} us grid) -> {path}",
            probes.samples.len(),
            probes.interval_us
        );
    }
    Ok(())
}

/// Check a `--trace-out` artifact against the Chrome trace-event format:
/// the schema tag, the `traceEvents` array, the keys every viewer needs
/// (`name`/`ph`/`ts`/`pid`/`tid`), and the sorted-timestamp invariant the
/// exporter guarantees. Returns the event count.
fn validate_chrome_trace(v: &crate::util::json::Value) -> crate::Result<usize> {
    let schema = v.req_str("schema")?;
    anyhow::ensure!(
        schema == "agentserve-trace-v1",
        "unknown trace schema '{schema}' (expected agentserve-trace-v1)"
    );
    let events = v.req_arr("traceEvents")?;
    let mut last_ts = 0.0f64;
    for (i, e) in events.iter().enumerate() {
        for key in ["name", "ph", "ts", "pid", "tid"] {
            anyhow::ensure!(e.get(key).is_some(), "event {i}: missing required key '{key}'");
        }
        let ts = e.req_f64("ts")?;
        match e.req_str("ph")? {
            "X" => anyhow::ensure!(
                e.req_f64("dur")? >= 0.0,
                "event {i}: complete ('X') event needs a non-negative dur"
            ),
            "i" => {}
            other => anyhow::bail!("event {i}: unexpected phase '{other}' (exporter emits X|i)"),
        }
        anyhow::ensure!(
            ts >= last_ts,
            "event {i}: ts {ts} out of order (the exporter sorts by timestamp)"
        );
        last_ts = ts;
    }
    Ok(events.len())
}

/// Filesystem-safe tag for a policy name (`llama.cpp` → `llama-cpp`).
fn policy_slug(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect()
}

/// Insert a per-policy slug before the extension: `ev.jsonl` → `ev-vllm.jsonl`.
/// Only the final path component is split, so dotted directories
/// (`runs.v2/ev`) never get the slug spliced into the directory name.
fn events_path(base: &str, slug: &str) -> String {
    let (dir, file) = match base.rsplit_once('/') {
        Some((d, f)) => (Some(d), f),
        None => (None, base),
    };
    let file = match file.rsplit_once('.') {
        Some((stem, ext)) if !stem.is_empty() => format!("{stem}-{slug}.{ext}"),
        _ => format!("{file}-{slug}"),
    };
    match dir {
        Some(d) => format!("{d}/{file}"),
        None => file,
    }
}

/// `agentserve scenario list|run|record|replay|sweep` — the scenario
/// engine CLI.
fn scenario_cmd(args: &Args) -> crate::Result<()> {
    use crate::engine::{record_scenario_trace, run_scenario, run_scenario_recorded, run_sim_trace};
    use crate::workload::Scenario;

    let model: ModelKind = args.get_or("model", "3b").parse()?;
    let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
    let seed = args.get_u64("seed", 7)?;
    let mut cfg = match args.get("config") {
        Some(p) => Config::from_path(p)?,
        None => Config::preset(model, gpu),
    };

    match args.action.as_deref() {
        Some("list") => {
            println!("built-in scenarios:");
            for s in Scenario::registry() {
                println!(
                    "  {:<16} {:>3} sessions  {:<11} {}",
                    s.name,
                    s.total_sessions,
                    s.arrivals.kind_name(),
                    s.description
                );
            }
            println!("\nbuilt-in sweeps (scenario sweep --name <sweep>):");
            for s in crate::workload::SweepSpec::registry() {
                println!(
                    "  {:<16} {:>3} points    {:<11} {}",
                    s.name,
                    s.axis.len(),
                    s.axis.kind_name(),
                    s.description
                );
            }
            Ok(())
        }
        Some("run") => {
            // Loud refusal over silent drop: the control plane scales a
            // fleet, and `scenario run` has no fleet to scale.
            for flag in ["autoscale", "min-replicas", "max-replicas"] {
                anyhow::ensure!(
                    !args.has(flag),
                    "--{flag} drives the fleet control plane; single-GPU `scenario run` \
                     has no fleet to scale — use `agentserve cluster run --autoscale`"
                );
            }
            let mut scenario = load_scenario_arg(args, &mut cfg)?;
            scenario.validate()?;
            if apply_kv_flags(args, &mut cfg, scenario.kv)? {
                scenario.kv = None;
            }
            if apply_host_flags(args, &mut cfg, scenario.host.clone())? {
                scenario.host = None;
            }
            let (trace_base, probe_base) = apply_obs_flags(args, &mut scenario)?;
            println!(
                "== scenario '{}' | {} | {} | seed {} ==",
                scenario.name, model, gpu, seed
            );
            // --exec-out is the documented name (ROADMAP: step-level
            // execution-log replay); --events remains as the original alias.
            let events_base = args.get("exec-out").or_else(|| args.get("events"));
            let policies = scenario_policies(args)?;
            let multi = policies.len() > 1;
            for policy in policies {
                // Only pay for event recording when the log is kept.
                let (out, exec) = if events_base.is_some() {
                    let (out, exec) = run_scenario_recorded(&cfg, policy, &scenario, seed);
                    (out, Some(exec))
                } else {
                    (run_scenario(&cfg, policy, &scenario, seed), None)
                };
                print_scenario_outcome(&out);
                if let (Some(base), Some(exec)) = (events_base, &exec) {
                    // One file per policy so --all-policies doesn't clobber.
                    let path = if multi {
                        events_path(base, &policy_slug(&out.policy_name))
                    } else {
                        base.to_string()
                    };
                    exec.save(&path)?;
                    println!("  {} execution events -> {path}", exec.len());
                }
                let slug = multi.then(|| policy_slug(&out.policy_name));
                save_obs_artifacts(
                    trace_base.as_deref(),
                    probe_base.as_deref(),
                    slug.as_deref(),
                    out.obs.as_ref(),
                    out.phases.as_ref(),
                )?;
            }
            Ok(())
        }
        Some("record") => {
            refuse_capture_flags(
                args,
                "`scenario record` writes a workload trace via --out — capture \
                 telemetry on a live run with `agentserve scenario run`",
            )?;
            let mut scenario = load_scenario_arg(args, &mut cfg)?;
            scenario.validate()?;
            if apply_kv_flags(args, &mut cfg, scenario.kv)? {
                scenario.kv = None;
            }
            let out_path = args.get_or("out", "trace.jsonl");
            let policy: Policy = args.get_or("policy", "agentserve").parse()?;
            let (out, trace) = record_scenario_trace(&cfg, policy, &scenario, seed);
            print_scenario_outcome(&out);
            trace.save_jsonl(out_path)?;
            println!("recorded {} sessions -> {out_path}", trace.len());
            Ok(())
        }
        Some("sweep") => {
            refuse_capture_flags(
                args,
                "a sweep aggregates many runs — capture one grid point via \
                 `agentserve scenario run` (the per-point scenario is printed \
                 by `scenario list`)",
            )?;
            let spec = resolve_sweep_spec(args, &mut cfg)?;
            spec.validate()?;
            // Sweeps default to comparing the whole paper lineup; --policy
            // narrows to one (for quick smokes).
            let policies = match args.get("policy") {
                Some(p) => vec![p.parse::<Policy>()?],
                None => Policy::paper_lineup(),
            };
            println!(
                "== sweep '{}' | axis {} ({}) | {} | {} | seed {} ==",
                spec.name,
                spec.axis.kind_name(),
                spec.axis.unit(),
                model,
                gpu,
                seed
            );
            let threads = grid_threads_arg(args)?;
            let report =
                crate::workload::run_sweep_with_threads(&cfg, &spec, &policies, seed, threads)?;
            print_sweep_report(&report);
            if let Some(path) = args.get("out") {
                report.save_json(path)?;
                println!("sweep report -> {path}");
            }
            if let Some(path) = args.get("csv") {
                report.save_csv(path)?;
                println!("sweep CSV -> {path}");
            }
            Ok(())
        }
        Some("replay") => {
            refuse_capture_flags(
                args,
                "`scenario replay` re-drives a recorded workload trace — \
                 capture telemetry on a live run with `agentserve scenario run`",
            )?;
            apply_kv_flags(args, &mut cfg, None)?;
            let path = args
                .get("trace")
                .ok_or_else(|| anyhow::anyhow!("scenario replay needs --trace <file>"))?;
            let trace = load_trace_any(path)?;
            anyhow::ensure!(!trace.is_empty(), "trace '{path}' has no sessions");
            println!(
                "== replaying {} sessions ({} decode tokens scripted) ==",
                trace.len(),
                trace.total_decode_tokens()
            );
            for policy in scenario_policies(args)? {
                let out = run_sim_trace(&cfg, policy, &trace);
                print_scenario_outcome(&out);
                anyhow::ensure!(
                    out.report.total_tokens == trace.total_decode_tokens(),
                    "replay must conserve scripted decode tokens"
                );
                if args.has("verify") {
                    let again = run_sim_trace(&cfg, policy, &trace);
                    anyhow::ensure!(
                        again.report.to_value().to_string() == out.report.to_value().to_string(),
                        "{}: two consecutive replays diverged",
                        out.policy_name
                    );
                    println!("  verify: two consecutive replays identical");
                }
            }
            Ok(())
        }
        other => {
            eprintln!("{USAGE}");
            match other {
                Some(a) => anyhow::bail!("unknown scenario action '{a}'"),
                None => anyhow::bail!("scenario needs an action: list|run|record|replay|sweep"),
            }
        }
    }
}

/// `agentserve workflow list|run` — the workflow DAG engine CLI.
///
/// `run` wraps the named registry workflow in an open-loop Poisson carrier
/// scenario (`--tasks` task releases at `--rate`/s) and drives it through
/// the simulator's dependency-driven arrival source, reporting task-level
/// makespan / critical-path / task-SLO metrics alongside the usual
/// per-request ones.
fn workflow_cmd(args: &Args) -> crate::Result<()> {
    use crate::engine::{run_scenario, run_scenario_recorded};
    use crate::workflow::{WorkflowLoad, WorkflowSpec};

    match args.action.as_deref() {
        Some("list") => {
            println!("built-in workflows (workflow run --name <workflow>):");
            for w in WorkflowSpec::registry() {
                println!(
                    "  {:<18} {:>2} nodes  {:>2} sessions/task  {}",
                    w.name,
                    w.nodes.len(),
                    w.sessions_per_task(),
                    w.description
                );
            }
            Ok(())
        }
        Some("run") => {
            let model: ModelKind = args.get_or("model", "3b").parse()?;
            let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
            let seed = args.get_u64("seed", 7)?;
            let mut cfg = Config::preset(model, gpu);
            let name = args
                .get("name")
                .ok_or_else(|| anyhow::anyhow!("workflow run needs --name <workflow>"))?;
            let spec = WorkflowSpec::by_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown workflow '{name}' (try `agentserve workflow list`)")
            })?;
            let tasks = args.get_usize("tasks", 12)?;
            let rate = args.get_f64("rate", 0.5)?;
            let fan_out = match args.get("fan-out") {
                Some(v) => Some(v.parse::<usize>()?),
                None => None,
            };
            if let Some(ms) = args.get("task-slo-ms") {
                cfg.slo.task_ms = ms.parse()?;
            }
            apply_kv_flags(args, &mut cfg, None)?;
            apply_host_flags(args, &mut cfg, None)?;
            // --fail-prob installs the scenario-level tool-fault override
            // (every tool node; 3 attempts, exponential backoff).
            let tool_fault = match args.get("fail-prob") {
                Some(p) => Some(crate::workflow::ToolFaultPolicy::with_fail_prob(p.parse()?)),
                None => None,
            };
            let mut scenario = WorkflowLoad { spec, fan_out, tool_fault }.carrier(tasks, rate);
            scenario.validate()?;
            let (trace_base, probe_base) = apply_obs_flags(args, &mut scenario)?;
            let per_task = scenario
                .workflow
                .as_ref()
                .expect("just built")
                .effective_spec()
                .sessions_per_task();
            println!(
                "== workflow '{}' | {} tasks x {} sessions | {} | {} | seed {} ==",
                scenario.name, tasks, per_task, model, gpu, seed
            );
            let exec_base = args.get("exec-out");
            let policies = scenario_policies(args)?;
            let multi = policies.len() > 1;
            for policy in policies {
                let (out, exec) = if exec_base.is_some() {
                    let (out, exec) = run_scenario_recorded(&cfg, policy, &scenario, seed);
                    (out, Some(exec))
                } else {
                    (run_scenario(&cfg, policy, &scenario, seed), None)
                };
                print_scenario_outcome(&out);
                if let (Some(base), Some(exec)) = (exec_base, &exec) {
                    let path = if multi {
                        events_path(base, &policy_slug(&out.policy_name))
                    } else {
                        base.to_string()
                    };
                    exec.save(&path)?;
                    println!("  {} execution events -> {path}", exec.len());
                }
                let slug = multi.then(|| policy_slug(&out.policy_name));
                save_obs_artifacts(
                    trace_base.as_deref(),
                    probe_base.as_deref(),
                    slug.as_deref(),
                    out.obs.as_ref(),
                    out.phases.as_ref(),
                )?;
            }
            Ok(())
        }
        other => {
            eprintln!("{USAGE}");
            match other {
                Some(a) => anyhow::bail!("unknown workflow action '{a}'"),
                None => anyhow::bail!("workflow needs an action: list|run"),
            }
        }
    }
}

/// `agentserve cluster list|run|sweep` — the fleet layer CLI.
///
/// `run` drives a scenario on an N-replica fleet behind a session router
/// and prints the [`crate::metrics::FleetReport`]; `sweep` runs the
/// replica (capacity-planning) axis — the registry `gpus-for-slo` sweep or
/// an ad-hoc `--replica-counts` grid — and reports the *inverse* knee: the
/// smallest fleet meeting the TTFT SLO.
fn cluster_cmd(args: &Args) -> crate::Result<()> {
    use crate::cluster::{run_cluster, run_cluster_recorded};
    use crate::config::RouterPolicy;
    use crate::workload::{SweepAxis, SweepSpec};

    match args.action.as_deref() {
        Some("list") => {
            println!("router policies (cluster run --router <policy>):");
            for r in RouterPolicy::ALL {
                println!("  {:<18} {}", r.name(), r.describe());
            }
            println!("\nfleet sweeps (cluster sweep --name <sweep>):");
            for s in SweepSpec::registry() {
                match &s.axis {
                    SweepAxis::Replicas { counts, router } => println!(
                        "  {:<16} {:?} replicas  {:<11} {}",
                        s.name,
                        counts,
                        router.name(),
                        s.description
                    ),
                    SweepAxis::Chaos { rates_per_min, replicas, router } => println!(
                        "  {:<16} {:?} crashes/min x{} {:<11} {}",
                        s.name,
                        rates_per_min,
                        replicas,
                        router.name(),
                        s.description
                    ),
                    SweepAxis::Autoscale { up_threshes, min_replicas, max_replicas, router } => {
                        println!(
                            "  {:<16} {:?} up-thresh [{},{}] {:<11} {}",
                            s.name,
                            up_threshes,
                            min_replicas,
                            max_replicas,
                            router.name(),
                            s.description
                        )
                    }
                    _ => {}
                }
            }
            Ok(())
        }
        Some("run") => {
            let model: ModelKind = args.get_or("model", "3b").parse()?;
            let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
            let seed = args.get_u64("seed", 7)?;
            let mut cfg = match args.get("config") {
                Some(p) => Config::from_path(p)?,
                None => Config::preset(model, gpu),
            };
            let mut scenario = load_scenario_arg(args, &mut cfg)?;
            scenario.validate()?;
            if apply_kv_flags(args, &mut cfg, scenario.kv)? {
                scenario.kv = None;
            }
            if apply_host_flags(args, &mut cfg, scenario.host.clone())? {
                scenario.host = None;
            }
            let (trace_base, probe_base) = apply_obs_flags(args, &mut scenario)?;
            // --autoscale hands the fleet size to the control plane: it
            // conflicts with an explicit static --replicas, and the band
            // flags mean nothing without it (loud refusal over silent drop).
            let autoscale_on = args.has("autoscale");
            anyhow::ensure!(
                !(autoscale_on && args.has("replicas")),
                "--autoscale manages the fleet size (starting at the band floor); \
                 drop --replicas, or drop --autoscale for a static fleet"
            );
            anyhow::ensure!(
                autoscale_on || !(args.has("min-replicas") || args.has("max-replicas")),
                "--min-replicas/--max-replicas set the autoscale band; pass --autoscale \
                 to enable the control plane (or --replicas N for a static fleet)"
            );
            let mut replicas = args.get_usize("replicas", cfg.cluster.replicas)?;
            anyhow::ensure!(replicas >= 1, "--replicas must be >= 1");
            if autoscale_on {
                use crate::config::AutoscaleConfig;
                // Start from the scenario's own policy when it carries an
                // active one (e.g. diurnal-burst), else the banded default;
                // the CLI band flags override in either case.
                let mut a = scenario
                    .autoscale
                    .clone()
                    .filter(|a| a.is_active())
                    .unwrap_or_else(|| AutoscaleConfig::banded(1, 4));
                a.min_replicas = args.get_usize("min-replicas", a.min_replicas)?;
                a.max_replicas = args.get_usize("max-replicas", a.max_replicas)?;
                a.validate()?;
                replicas = a.min_replicas;
                scenario.autoscale = Some(a);
            }
            let router: RouterPolicy = match args.get("router") {
                Some(r) => r.parse()?,
                None => cfg.cluster.router,
            };
            // --fail-rate seeds the replica-crash process (crashes per
            // replica per virtual minute; 0 strips chaos — the fault-free
            // baseline); --restart-ms tunes the cold-restart latency of an
            // active process (seeded here or carried by the scenario).
            let fail_rate = match args.get("fail-rate") {
                Some(r) => Some(r.parse::<f64>()?),
                None => None,
            };
            let restart_ms = match args.get("restart-ms") {
                Some(m) => Some(m.parse::<u64>()?),
                None => None,
            };
            if fail_rate.is_some() || restart_ms.is_some() {
                use crate::config::ChaosConfig;
                let mut chaos = scenario.chaos.clone().unwrap_or_else(|| ChaosConfig::seeded(0));
                if let Some(rate) = fail_rate {
                    anyhow::ensure!(
                        rate.is_finite() && rate >= 0.0,
                        "--fail-rate must be finite and >= 0 (crashes/replica/min; 0 = off)"
                    );
                    chaos.mtbf_us =
                        if rate > 0.0 { (60_000_000.0 / rate) as u64 } else { 0 };
                }
                if let Some(ms) = restart_ms {
                    chaos.restart_us = ms.saturating_mul(1000);
                }
                // Loud refusal over silent drop: --restart-ms with nothing
                // to restart would otherwise do nothing.
                anyhow::ensure!(
                    chaos.is_active() || restart_ms.is_none(),
                    "--restart-ms tunes an active crash process; pass --fail-rate > 0 \
                     or a chaos-carrying scenario (e.g. failure-storm)"
                );
                scenario.chaos = chaos.is_active().then_some(chaos);
                scenario.validate()?;
            }
            match scenario.autoscale.as_ref().filter(|a| a.is_active()) {
                Some(a) => println!(
                    "== cluster '{}' | autoscale [{}, {}] replicas | router {} | {} | {} \
                     | seed {} ==",
                    scenario.name, a.min_replicas, a.max_replicas, router, model, gpu, seed
                ),
                None => println!(
                    "== cluster '{}' | {} replicas | router {} | {} | {} | seed {} ==",
                    scenario.name, replicas, router, model, gpu, seed
                ),
            }
            // The fleet merge stamps every event with its replica, so the
            // exec log works here too; --events stays as the alias.
            let exec_base = args.get("exec-out").or_else(|| args.get("events"));
            let policies = scenario_policies(args)?;
            let multi = policies.len() > 1;
            for policy in policies {
                let (out, exec) = if exec_base.is_some() {
                    let (out, exec) =
                        run_cluster_recorded(&cfg, policy, &scenario, replicas, router, seed)?;
                    (out, Some(exec))
                } else {
                    (run_cluster(&cfg, policy, &scenario, replicas, router, seed)?, None)
                };
                println!("--- {} ---", out.policy_name);
                println!("{}", out.report);
                if args.has("per-replica") {
                    for (r, o) in out.per_replica.iter().enumerate() {
                        println!(
                            "  r{r}    sessions={}/{} tokens={} ttft p99 {:.0}ms",
                            o.report.completed_sessions,
                            o.report.sessions,
                            o.report.total_tokens,
                            o.report.ttft.p99
                        );
                    }
                }
                if let (Some(base), Some(exec)) = (exec_base, &exec) {
                    let path = if multi {
                        events_path(base, &policy_slug(&out.policy_name))
                    } else {
                        base.to_string()
                    };
                    exec.save(&path)?;
                    println!("  {} execution events -> {path}", exec.len());
                }
                let slug = multi.then(|| policy_slug(&out.policy_name));
                save_obs_artifacts(
                    trace_base.as_deref(),
                    probe_base.as_deref(),
                    slug.as_deref(),
                    out.obs.as_ref(),
                    out.report.phases.as_ref(),
                )?;
            }
            Ok(())
        }
        Some("sweep") => {
            refuse_capture_flags(
                args,
                "a fleet sweep aggregates many runs — capture one grid point \
                 via `agentserve cluster run`",
            )?;
            let model: ModelKind = args.get_or("model", "3b").parse()?;
            let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
            let seed = args.get_u64("seed", 7)?;
            let mut cfg = match args.get("config") {
                Some(p) => Config::from_path(p)?,
                None => Config::preset(model, gpu),
            };
            // Fleet grids vary replicas only; refuse the scenario-sweep
            // axis flags instead of silently dropping them (the grid the
            // user asked for must be the grid run).
            for flag in ["rates", "agents", "mix", "kv-blocks", "fan-outs", "cpu-workers"] {
                anyhow::ensure!(
                    args.get(flag).is_none(),
                    "--{flag} is a scenario-sweep axis; `cluster sweep` grids vary the \
                     fleet (replica count or crash rate) only — use \
                     `agentserve scenario sweep` for that axis"
                );
            }
            let spec = if let Some(name) = args.get("name") {
                // Refuse flags the registry sweep would silently drop —
                // including --router: the grid's router is baked into the
                // registry definition.
                anyhow::ensure!(
                    args.get("replica-counts").is_none()
                        && args.get("chaos").is_none()
                        && args.get("scenario").is_none()
                        && args.get("file").is_none()
                        && args.get("router").is_none(),
                    "--name picks a built-in fleet sweep (fixed grid and router); \
                     drop it to build an ad-hoc --replica-counts/--chaos grid"
                );
                let spec = SweepSpec::by_name(name).ok_or_else(|| {
                    anyhow::anyhow!("unknown sweep '{name}' (try `agentserve cluster list`)")
                })?;
                anyhow::ensure!(
                    matches!(
                        spec.axis,
                        SweepAxis::Replicas { .. }
                            | SweepAxis::Chaos { .. }
                            | SweepAxis::Autoscale { .. }
                    ),
                    "sweep '{name}' is not a fleet (replicas/chaos/autoscale-axis) sweep; \
                     run it via `agentserve scenario sweep --name {name}`"
                );
                spec
            } else {
                let base = if let Some(path) = args.get("file") {
                    scenario_from_file(path, &mut cfg)?
                } else if let Some(name) = args.get("scenario") {
                    crate::workload::Scenario::by_name(name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown scenario '{name}' (try `agentserve scenario list`)"
                        )
                    })?
                } else {
                    anyhow::bail!(
                        "cluster sweep needs --name <fleet-sweep>, or a base scenario \
                         (--scenario <name> | --file f.json) plus --replica-counts n1,n2,…"
                    )
                };
                let counts = args.get_usize_list("replica-counts")?;
                let chaos_rates = args.get_f64_list("chaos")?;
                let router: RouterPolicy = match args.get("router") {
                    Some(r) => r.parse()?,
                    None => cfg.cluster.router,
                };
                let axis = match (counts, chaos_rates) {
                    (Some(counts), None) => SweepAxis::Replicas { counts, router },
                    (None, Some(rates_per_min)) => SweepAxis::Chaos {
                        rates_per_min,
                        replicas: args.get_usize("replicas", cfg.cluster.replicas)?,
                        router,
                    },
                    _ => anyhow::bail!(
                        "pass exactly one fleet axis: --replica-counts n1,n2,… | \
                         --chaos r1,r2,… (crashes/replica/min)"
                    ),
                };
                SweepSpec {
                    name: format!("{}-fleet-sweep", base.name),
                    description: format!(
                        "ad-hoc {} sweep over '{}' ({} router)",
                        axis.kind_name(),
                        base.name,
                        router
                    ),
                    base,
                    axis,
                }
            };
            spec.validate()?;
            let policies = match args.get("policy") {
                Some(p) => vec![p.parse::<Policy>()?],
                None => Policy::paper_lineup(),
            };
            println!(
                "== fleet sweep '{}' | axis {} ({}) | {} | {} | seed {} ==",
                spec.name,
                spec.axis.kind_name(),
                spec.axis.unit(),
                model,
                gpu,
                seed
            );
            let threads = grid_threads_arg(args)?;
            let report =
                crate::workload::run_sweep_with_threads(&cfg, &spec, &policies, seed, threads)?;
            print_sweep_report(&report);
            if let Some(path) = args.get("out") {
                report.save_json(path)?;
                println!("sweep report -> {path}");
            }
            if let Some(path) = args.get("csv") {
                report.save_csv(path)?;
                println!("sweep CSV -> {path}");
            }
            Ok(())
        }
        other => {
            eprintln!("{USAGE}");
            match other {
                Some(a) => anyhow::bail!("unknown cluster action '{a}'"),
                None => anyhow::bail!("cluster needs an action: list|run|sweep"),
            }
        }
    }
}

/// `agentserve probe` — run a scenario with the time-series sampler on
/// and dump the probe log: pretty JSON to stdout, or to `--out` (CSV when
/// the path ends in `.csv`). `--replicas`/`--router` lift the same run
/// onto the fleet, where the shared grid samples every serving replica at
/// each tick.
fn probe_cmd(args: &Args) -> crate::Result<()> {
    use crate::config::RouterPolicy;
    let model: ModelKind = args.get_or("model", "3b").parse()?;
    let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
    let seed = args.get_u64("seed", 7)?;
    let mut cfg = match args.get("config") {
        Some(p) => Config::from_path(p)?,
        None => Config::preset(model, gpu),
    };
    let mut scenario = load_scenario_arg(args, &mut cfg)?;
    scenario.validate()?;
    // Layer the sampler onto whatever obs block the scenario carries, so
    // a traced scenario file keeps its spans; the CLI owns the grid.
    let mut obs = scenario.obs.unwrap_or_default();
    obs.probe.interval_us = args.get_u64("interval-us", 50_000)?;
    obs.validate()?;
    scenario.obs = Some(obs);
    let policy: Policy = args.get_or("policy", "agentserve").parse()?;
    let probes = if let Some(r) = args.get("replicas") {
        let replicas: usize = r.parse()?;
        anyhow::ensure!(replicas >= 1, "--replicas must be >= 1");
        let router: RouterPolicy = match args.get("router") {
            Some(r) => r.parse()?,
            None => cfg.cluster.router,
        };
        let out = crate::cluster::run_cluster(&cfg, policy, &scenario, replicas, router, seed)?;
        out.obs.and_then(|l| l.probes)
    } else {
        // Loud refusal over silent drop: a router with no fleet to route.
        anyhow::ensure!(
            args.get("router").is_none(),
            "--router routes a fleet; pass --replicas N to probe one"
        );
        let out = crate::engine::run_scenario(&cfg, policy, &scenario, seed);
        out.obs.and_then(|l| l.probes)
    };
    let probes =
        probes.ok_or_else(|| anyhow::anyhow!("probed run kept no sample log (bug)"))?;
    match args.get("out") {
        Some(path) => {
            if path.ends_with(".csv") {
                std::fs::write(path, probes.to_csv())?;
            } else {
                std::fs::write(path, probes.to_value().to_string_pretty())?;
            }
            println!(
                "{} probe samples ({} us grid) -> {path}",
                probes.samples.len(),
                probes.interval_us
            );
        }
        None => println!("{}", probes.to_value().to_string_pretty()),
    }
    Ok(())
}

/// `agentserve trace validate` — check a `--trace-out` artifact against
/// the Chrome trace-event format without leaving the CLI.
fn trace_cmd(args: &Args) -> crate::Result<()> {
    match args.action.as_deref() {
        Some("validate") => {
            let path = args
                .get("file")
                .ok_or_else(|| anyhow::anyhow!("trace validate needs --file <trace.json>"))?;
            let v = crate::util::json::parse(&std::fs::read_to_string(path)?)?;
            let n = validate_chrome_trace(&v)?;
            println!("trace '{path}' is well-formed ({n} events)");
            Ok(())
        }
        other => {
            eprintln!("{USAGE}");
            match other {
                Some(a) => anyhow::bail!("unknown trace action '{a}'"),
                None => anyhow::bail!("trace needs an action: validate"),
            }
        }
    }
}

/// Resolve `scenario sweep` inputs: `--name` picks a built-in sweep;
/// otherwise a base scenario (`--scenario` registry name or `--file`, which
/// may embed config overrides) plus exactly one axis flag builds an ad-hoc
/// spec.
fn resolve_sweep_spec(
    args: &Args,
    cfg: &mut Config,
) -> crate::Result<crate::workload::SweepSpec> {
    use crate::workload::{Scenario, SweepAxis, SweepSpec};
    if let Some(name) = args.get("name") {
        // A registry sweep is fully specified: refuse flags that would be
        // silently dropped (the grid the user asked for must be the grid run).
        for flag in [
            "scenario",
            "file",
            "rates",
            "agents",
            "mix",
            "kv-blocks",
            "fan-outs",
            "cpu-workers",
            "replica-counts",
            "chaos",
            "router",
        ] {
            anyhow::ensure!(
                args.get(flag).is_none(),
                "--name picks a built-in sweep; --{flag} would be ignored — \
                 drop --name to build an ad-hoc sweep"
            );
        }
        return SweepSpec::by_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown sweep '{name}' (try `agentserve scenario list`)")
        });
    }
    // No ad-hoc `scenario sweep` axis uses a router; refuse rather than
    // silently drop it (fleet grids live under `agentserve cluster sweep`).
    anyhow::ensure!(
        args.get("router").is_none(),
        "--router applies to fleet (replica) grids; use `agentserve cluster sweep`"
    );
    anyhow::ensure!(
        args.get("chaos").is_none(),
        "--chaos is a fleet axis; use `agentserve cluster sweep`"
    );
    let base = if let Some(path) = args.get("file") {
        scenario_from_file(path, cfg)?
    } else if let Some(name) = args.get("scenario") {
        Scenario::by_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario '{name}' (try `agentserve scenario list`)")
        })?
    } else {
        anyhow::bail!(
            "scenario sweep needs --name <sweep>, or a base scenario \
             (--scenario <name> | --file <scenario.json>) plus an axis flag"
        )
    };
    let rates = args.get_f64_list("rates")?;
    let agents = args.get_usize_list("agents")?;
    let mix = args.get_f64_list("mix")?;
    let kv_blocks = args.get_usize_list("kv-blocks")?;
    let fan_outs = args.get_usize_list("fan-outs")?;
    let cpu_workers = args.get_usize_list("cpu-workers")?;
    let n_axes = [
        rates.is_some(),
        agents.is_some(),
        mix.is_some(),
        kv_blocks.is_some(),
        fan_outs.is_some(),
        cpu_workers.is_some(),
    ]
    .iter()
    .filter(|&&x| x)
    .count();
    anyhow::ensure!(
        n_axes == 1,
        "pass exactly one sweep axis: --rates r1,r2,… | --agents n1,n2,… | \
         --mix f1,f2,… | --kv-blocks b1,b2,… | --fan-outs d1,d2,… | \
         --cpu-workers c1,c2,…"
    );
    let axis = if let Some(r) = rates {
        SweepAxis::ArrivalRate(r)
    } else if let Some(a) = agents {
        SweepAxis::AgentCount(a)
    } else if let Some(m) = mix {
        SweepAxis::MixRatio(m)
    } else if let Some(b) = kv_blocks {
        SweepAxis::KvBlocks(b)
    } else if let Some(c) = cpu_workers {
        SweepAxis::CpuWorkers(c)
    } else {
        SweepAxis::FanOut(fan_outs.expect("one axis is set"))
    };
    Ok(SweepSpec {
        name: format!("{}-sweep", base.name),
        description: format!("ad-hoc {} sweep over '{}'", axis.kind_name(), base.name),
        base,
        axis,
    })
}

/// Render a sweep report: one block per grid point, then the knee summary.
fn print_sweep_report(report: &crate::workload::SweepReport) {
    for point in &report.points {
        println!(
            "-- {} {} {} | {} sessions | seed {} --",
            report.axis, point.axis_value, report.axis_unit, point.sessions, point.seed
        );
        println!(
            "   {:<11} {:>10} {:>10} {:>10} {:>9} {:>7} {:>7} {:>8}",
            "policy", "TTFT p50", "TTFT p99", "TPOT p99", "tok/s", "SLO", "evict", "preempt"
        );
        for pp in &point.per_policy {
            println!(
                "   {:<11} {:>8.0}ms {:>8.0}ms {:>8.1}ms {:>9.1} {:>6.1}% {:>7} {:>8}",
                pp.policy,
                pp.ttft_p50,
                pp.ttft_p99,
                pp.tpot_p99,
                pp.throughput_tok_s,
                pp.slo_rate * 100.0,
                pp.evictions,
                pp.preemptions
            );
        }
    }
    if report.axis == "replicas" {
        println!(
            "inverse knee (smallest fleet whose p99 TTFT meets the {:.0} ms SLO):",
            report.slo_ttft_ms
        );
    } else if report.axis == "fan-out" {
        println!(
            "task knee ({} where p99 makespan first exceeds the {:.0} ms task SLO):",
            report.axis, report.slo_task_ms
        );
    } else if report.axis == "chaos" {
        println!(
            "resilience knee (crash rate where p99 TTFT first exceeds the {:.0} ms SLO):",
            report.slo_ttft_ms
        );
    } else if report.axis == "kv-blocks" {
        println!(
            "memory knee (largest {} whose p99 TTFT still violates the {:.0} ms SLO):",
            report.axis, report.slo_ttft_ms
        );
    } else if report.axis == "cpu-workers" {
        println!(
            "host knee (smallest {} whose p99 task makespan meets the {:.0} ms task SLO):",
            report.axis, report.slo_task_ms
        );
    } else if report.axis == "autoscale" {
        println!(
            "frontier knee (first up-thresh too sluggish to hold the {:.0} ms TTFT SLO):",
            report.slo_ttft_ms
        );
    } else {
        println!(
            "knee ({} where p99 TTFT first exceeds the {:.0} ms SLO):",
            report.axis, report.slo_ttft_ms
        );
    }
    for (policy, knee) in &report.knees {
        match knee {
            Some(v) => println!("   {:<11} {} {}", policy, v, report.axis_unit),
            None => println!("   {:<11} none within the grid", policy),
        }
    }
}

/// `agentserve experiment run|example` — manifest-driven multi-axis grids
/// executed over the parallel worker pool with a deterministic merge.
fn experiment_cmd(args: &Args) -> crate::Result<()> {
    use crate::workload::ExperimentSpec;
    match args.action.as_deref() {
        Some("example") => {
            println!("{}", ExperimentSpec::example_manifest().to_string_pretty());
            Ok(())
        }
        Some("run") => {
            // The manifest owns the policy lineup; refuse flags that would
            // silently fight it (loud refusal over silent drop).
            for flag in ["policy", "all-policies"] {
                anyhow::ensure!(
                    !args.has(flag),
                    "--{flag} conflicts with the manifest's own \"policies\" list — \
                     edit the manifest instead"
                );
            }
            let model: ModelKind = args.get_or("model", "3b").parse()?;
            let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
            let path = args
                .get("file")
                .ok_or_else(|| anyhow::anyhow!("experiment run needs --file <manifest.json>"))?;
            let v = crate::util::json::parse(&std::fs::read_to_string(path)?)?;
            let mut cfg = Config::preset(model, gpu);
            // Manifests may embed sparse engine overrides, like scenario
            // files ("config" is allowlisted by the manifest parser).
            if let Some(overrides) = v.get("config") {
                cfg.apply_overrides(overrides)?;
                cfg.validate()?;
            }
            let spec = ExperimentSpec::from_value(&v)?;
            spec.validate()?;
            // Seed precedence: --seed beats the manifest's "seed" beats 7.
            let base_seed = match args.get("seed") {
                Some(s) => s.parse()?,
                None => spec.seed.unwrap_or(7),
            };
            let threads = grid_threads_arg(args)?;
            println!(
                "== experiment '{}' | {} cells x {} policies | {} | {} | seed {} ==",
                spec.name,
                spec.n_cells(),
                spec.policies.len(),
                model,
                gpu,
                base_seed
            );
            let report = crate::workload::run_experiment(&cfg, &spec, base_seed, threads)?;
            print_experiment_report(&report);
            if let Some(p) = args.get("out") {
                report.save_json(p)?;
                println!("experiment report -> {p}");
            }
            if let Some(p) = args.get("csv") {
                report.save_csv(p)?;
                println!("experiment CSV -> {p}");
            }
            Ok(())
        }
        other => {
            eprintln!("{USAGE}");
            match other {
                Some(a) => anyhow::bail!("unknown experiment action '{a}'"),
                None => anyhow::bail!("experiment needs an action: run|example"),
            }
        }
    }
}

/// Render an experiment report: one block per cell, policies as rows.
fn print_experiment_report(report: &crate::workload::ExperimentReport) {
    for cell in &report.cells {
        let coords = cell
            .coords
            .iter()
            .map(|(a, v)| format!("{}={v}", a.name()))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "-- cell {} | {coords} | {} sessions | seed {}{} --",
            cell.index,
            cell.sessions,
            cell.seed,
            if cell.overridden { " | overridden" } else { "" }
        );
        for pp in &cell.per_policy {
            println!(
                "   {:<11} TTFT p99 {:>7.0}ms  TPOT p99 {:>7.1}ms  {:>9.1} tok/s  SLO {:>5.1}%",
                pp.policy,
                pp.ttft_p99,
                pp.tpot_p99,
                pp.throughput_tok_s,
                pp.slo_rate * 100.0
            );
        }
    }
}

/// `agentserve bench suite` — time every registry sweep through the shared
/// sampling path and write the `BENCH_*.json` artifact the CI perf gate
/// diffs. Wall-clock is machine-local noise; the SLO metrics are seeded
/// sim results and must be identical on every machine.
fn bench_suite(args: &Args) -> crate::Result<()> {
    use crate::util::bench::{Bench, BenchPoint, BenchReport};
    let model: ModelKind = args.get_or("model", "3b").parse()?;
    let gpu: GpuKind = args.get_or("gpu", "a5000").parse()?;
    let seed = args.get_u64("seed", 7)?;
    let cfg = Config::preset(model, gpu);
    let policy: Policy = args.get_or("policy", "agentserve").parse()?;
    let threads = grid_threads_arg(args)?;
    // 1 warmup + 3 measured keeps the suite CI-friendly;
    // AGENTSERVE_BENCH_ITERS still overrides the measured count.
    let b = Bench::new("suite").with_iters(1, 3);
    let (_, measure) = b.iters();
    anyhow::ensure!(measure >= 1, "bench suite needs at least one measured iteration");
    let policies = [policy];
    let mut points = Vec::new();
    for spec in crate::workload::SweepSpec::registry() {
        let mut last: Option<crate::Result<crate::workload::SweepReport>> = None;
        let timing = b.case(&spec.name, || {
            last = Some(crate::workload::run_sweep_with_threads(
                &cfg, &spec, &policies, seed, threads,
            ));
        });
        let report = last.take().expect("measure >= 1 runs the closure")?;
        // Headline metrics off the highest-load grid point; the knee as a
        // metric with -1 encoding "none within the grid", so a knee
        // appearing or vanishing is itself a diffable change.
        let mut metrics = Vec::new();
        if let Some(pp) = report.points.last().and_then(|pt| pt.per_policy.first()) {
            metrics.push(("ttft_p99_ms".to_string(), pp.ttft_p99));
            metrics.push(("tpot_p99_ms".to_string(), pp.tpot_p99));
            metrics.push(("throughput_tok_s".to_string(), pp.throughput_tok_s));
            metrics.push(("slo_rate".to_string(), pp.slo_rate));
        }
        if let Some((_, knee)) = report.knees.first() {
            metrics.push(("knee".to_string(), knee.unwrap_or(-1.0)));
        }
        points.push(BenchPoint {
            name: format!("sweep/{}", spec.name),
            wall_ms: timing.median_us / 1000.0,
            min_ms: timing.min_us / 1000.0,
            metrics,
        });
    }
    // Scenario-run timing points for the fault/tide registry scenarios no
    // sweep covers: same seeded single-GPU fast path as `scenario run`, so
    // their SLO metrics are machine-independent too.
    for name in ["failure-storm", "diurnal-burst"] {
        let sc = crate::workload::Scenario::by_name(name).expect("registry scenario");
        let mut last: Option<crate::engine::SimOutcome> = None;
        let timing = b.case(name, || {
            last = Some(crate::engine::run_scenario_fast(&cfg, policy, &sc, seed));
        });
        let out = last.take().expect("measure >= 1 runs the closure");
        points.push(BenchPoint {
            name: format!("scenario/{name}"),
            wall_ms: timing.median_us / 1000.0,
            min_ms: timing.min_us / 1000.0,
            metrics: vec![
                ("ttft_p99_ms".to_string(), out.report.ttft.p99),
                ("tpot_p99_ms".to_string(), out.report.tpot.p99),
                ("slo_rate".to_string(), out.slo.rate()),
            ],
        });
    }
    // Traced timing point: the fig5 scenario with the full telemetry layer
    // on (spans + 50 ms probes + attribution), so the overhead of
    // observability itself is a diffable number in the perf gate — and the
    // attribution shares are machine-independent seeded metrics.
    {
        let mut sc = crate::workload::Scenario::by_name("paper-fig5").expect("registry scenario");
        sc.obs = Some(crate::config::ObsConfig {
            trace: true,
            probe: crate::config::ProbeConfig::every_us(50_000),
        });
        let mut last: Option<crate::engine::SimOutcome> = None;
        let timing = b.case("paper-fig5-traced", || {
            last = Some(crate::engine::run_scenario_fast(&cfg, policy, &sc, seed));
        });
        let out = last.take().expect("measure >= 1 runs the closure");
        let phases = out.phases.expect("active obs attaches attribution");
        let obs = out.obs.as_ref().expect("active obs attaches the span log");
        points.push(BenchPoint {
            name: "obs/paper-fig5-traced".to_string(),
            wall_ms: timing.median_us / 1000.0,
            min_ms: timing.min_us / 1000.0,
            metrics: vec![
                ("ttft_p99_ms".to_string(), out.report.ttft.p99),
                ("prefill_share".to_string(), phases.prefill_share()),
                ("decode_idle_share".to_string(), phases.decode_idle_share()),
                ("spans".to_string(), obs.spans.len() as f64),
            ],
        });
    }
    let report = BenchReport {
        label: args.get_or("label", "local").to_string(),
        model: cfg.model.kind.name().to_string(),
        gpu: cfg.gpu.kind.name().to_string(),
        threads,
        iters: measure,
        points,
    };
    let out = args.get_or("out", "BENCH.json");
    report.save(out)?;
    println!("bench artifact ({} points) -> {out}", report.points.len());
    Ok(())
}

/// `agentserve bench diff BASELINE.json NEW.json` — the CI regression gate.
/// Returns an error (non-zero exit) when any point regresses beyond
/// tolerance.
fn bench_diff(args: &Args) -> crate::Result<()> {
    use crate::util::bench::{diff_reports, BenchReport};
    let [old_path, new_path] = args.rest() else {
        anyhow::bail!(
            "bench diff needs exactly two artifacts: \
             agentserve bench diff BASELINE.json NEW.json"
        );
    };
    let wall_tol = args.get_f64("tolerance", 0.5)?;
    let metric_tol = args.get_f64("metric-tolerance", 0.0)?;
    anyhow::ensure!(
        wall_tol >= 0.0 && metric_tol >= 0.0,
        "tolerances are fractions >= 0 (0.5 = 50% slack)"
    );
    let old = BenchReport::load(old_path)?;
    let new = BenchReport::load(new_path)?;
    let diff = diff_reports(&old, &new, wall_tol, metric_tol)?;
    println!(
        "== bench diff | baseline '{}' vs '{}' | wall tol {:.0}% | metric tol {:.0}% ==",
        old.label,
        new.label,
        wall_tol * 100.0,
        metric_tol * 100.0
    );
    for row in &diff.rows {
        println!("  {row}");
    }
    for name in &diff.only_in_new {
        println!("  {name:<32} only in new artifact (no baseline)");
    }
    anyhow::ensure!(
        diff.regressions.is_empty(),
        "{} perf regression(s) beyond tolerance",
        diff.regressions.len()
    );
    println!("no regressions beyond tolerance");
    Ok(())
}

fn run_figures(args: &Args) -> crate::Result<()> {
    let all = args.has("all");
    let fig = args.get("fig").map(|f| f.parse::<u32>()).transpose()?;
    let table = args.get("table").map(|t| t.parse::<u32>()).transpose()?;
    let jd = args.get("json-dir");
    if all || fig == Some(2) {
        figures::fig2_tpot_timeline(jd)?;
    }
    if all || fig == Some(3) {
        figures::fig3_sm_curves(jd)?;
    }
    if all || fig == Some(5) {
        figures::fig5_latency_throughput(jd)?;
    }
    if all || fig == Some(6) {
        figures::fig6_slo_attainment(jd)?;
    }
    if all || fig == Some(7) {
        figures::fig7_ablation(jd)?;
    }
    if all || table == Some(1) {
        figures::table1_token_distribution(jd)?;
    }
    if !all && fig.is_none() && table.is_none() {
        anyhow::bail!("pass --fig N, --table N, or --all");
    }
    Ok(())
}

/// End-to-end demo on the real PJRT engine.
fn serve_real(args: &Args) -> crate::Result<()> {
    use crate::engine::real::{run_real, RealPolicy};
    use crate::workload::WorkloadGenerator;

    let artifacts = args.get_or("artifacts", "artifacts");
    let policy = match args.get_or("policy", "agentserve").to_ascii_lowercase().as_str() {
        "agentserve" => RealPolicy::AgentServe,
        "fcfs" | "fcfs-mixed" | "llamacpp" => RealPolicy::FcfsMixed,
        other => anyhow::bail!("unknown real policy: {other} (agentserve|fcfs)"),
    };
    let tool_scale = args.get_f64("tool-scale", 0.1)?;
    let mut engine = crate::runtime::PjrtEngine::load(artifacts)?;
    let n = args
        .get_usize("agents", 4)?
        .min(engine.geometry().decode_batch);
    let mut gen = WorkloadGenerator::new(WorkloadKind::ReAct, ModelKind::Tiny, 7);
    let scripts = gen.sessions(n);
    println!(
        "serving {n} concurrent ReAct sessions on the real engine ({} params)…",
        engine.geometry().param_count
    );
    let out = run_real(
        &mut engine,
        policy,
        scripts,
        crate::config::SchedulerConfig::calibrated(10.0),
        tool_scale,
    )?;
    println!("== {} (real PJRT compute) ==", out.policy);
    println!("{}", out.report);
    println!(
        "  engine: {} prefill calls ({} ms), {} decode calls ({} ms), {:.1} MB cache traffic",
        out.engine_stats.prefill_calls,
        out.engine_stats.prefill_us / 1000,
        out.engine_stats.decode_calls,
        out.engine_stats.decode_us / 1000,
        out.engine_stats.cache_roundtrip_bytes as f64 / 1e6
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn bench_subcommand_runs() {
        run(args("bench --model 3b --agents 3 --sessions 1")).unwrap();
    }

    #[test]
    fn unknown_subcommand_fails() {
        assert!(run(args("frobnicate")).is_err());
    }

    #[test]
    fn figures_requires_selection() {
        assert!(run(args("figures")).is_err());
    }

    #[test]
    fn analyze_runs() {
        run(args("analyze --model 7b --gpu 5090")).unwrap();
    }

    #[test]
    fn scenario_list_and_run_smoke() {
        run(args("scenario list")).unwrap();
        run(args("scenario run --name paper-fig5 --model 3b")).unwrap();
        assert!(run(args("scenario run --name no-such-scenario")).is_err());
        assert!(run(args("scenario")).is_err());
        assert!(run(args("scenario frobnicate")).is_err());
    }

    #[test]
    fn scenario_sweep_smoke_and_artifacts() {
        // A tiny 2-point grid under one policy, with JSON + CSV artifacts.
        let dir = std::env::temp_dir().join("agentserve_scenario_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("sweep.json");
        let csv = dir.join("sweep.csv");
        run(args(&format!(
            "scenario sweep --scenario paper-fig5 --rates 0.5,2 --policy vllm \
             --model 3b --out {} --csv {}",
            json.to_str().unwrap(),
            csv.to_str().unwrap()
        )))
        .unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(report.req_str("axis").unwrap(), "arrival-rate");
        assert_eq!(report.req_arr("points").unwrap().len(), 2);
        assert_eq!(report.req_arr("knees").unwrap().len(), 1);
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert_eq!(csv_text.lines().count(), 1 + 2, "header + one row per point×policy");
        std::fs::remove_file(json).unwrap();
        std::fs::remove_file(csv).unwrap();
    }

    #[test]
    fn scenario_sweep_flag_validation() {
        // Unknown sweep name.
        assert!(run(args("scenario sweep --name no-such-sweep")).is_err());
        // --name with flags that would be silently dropped is an error.
        assert!(run(args("scenario sweep --name agent-scaling --agents 3,4")).is_err());
        assert!(run(args("scenario sweep --name agent-scaling --scenario paper-fig5")).is_err());
        // No base scenario / axis at all.
        assert!(run(args("scenario sweep")).is_err());
        // Two axes at once.
        assert!(run(args(
            "scenario sweep --scenario paper-fig5 --rates 1,2 --agents 3,4"
        ))
        .is_err());
        // Axis without a base scenario.
        assert!(run(args("scenario sweep --rates 1,2")).is_err());
        // Non-increasing grid.
        assert!(run(args(
            "scenario sweep --scenario paper-fig5 --rates 2,1 --policy vllm"
        ))
        .is_err());
        // Mix axis on a single-population base.
        assert!(run(args(
            "scenario sweep --scenario paper-fig5 --mix 0.2,0.8 --policy vllm"
        ))
        .is_err());
    }

    #[test]
    fn scenario_run_with_kv_flags_smoke() {
        // Constrained pool + sharing on a small closed-loop scenario.
        run(args(
            "scenario run --name paper-fig5 --model 3b --kv-blocks 2048 --prefix-sharing",
        ))
        .unwrap();
        // A pool the validator knows is too small for one session errors.
        assert!(run(args("scenario run --name paper-fig5 --kv-blocks 16")).is_err());
    }

    #[test]
    fn scenario_sweep_kv_axis_smoke() {
        let dir = std::env::temp_dir().join("agentserve_kv_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("kv.json");
        let csv = dir.join("kv.csv");
        run(args(&format!(
            "scenario sweep --scenario open-loop-sweep --kv-blocks 640,65536 \
             --policy vllm --model 3b --out {} --csv {}",
            json.to_str().unwrap(),
            csv.to_str().unwrap()
        )))
        .unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(report.req_str("axis").unwrap(), "kv-blocks");
        assert_eq!(report.req_arr("points").unwrap().len(), 2);
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.lines().next().unwrap().contains("preemptions"));
        std::fs::remove_file(json).unwrap();
        std::fs::remove_file(csv).unwrap();
        // Axis validation: a grid value too small for one session errors,
        // and a registry sweep refuses a would-be-dropped axis flag.
        assert!(run(args(
            "scenario sweep --scenario open-loop-sweep --kv-blocks 128,640 --policy vllm"
        ))
        .is_err());
        assert!(run(args("scenario sweep --name kv-knee --kv-blocks 1024,2048")).is_err());
    }

    #[test]
    fn scenario_run_host_flags_smoke() {
        // The host-carrying registry scenarios run end to end.
        run(args("scenario run --name tool-storm --model 3b")).unwrap();
        run(args("scenario run --name slow-sandbox --model 3b")).unwrap();
        // CLI override onto a plain scenario, and the explicit legacy host
        // (--cpu-workers 0 strips an active scenario host).
        run(args(
            "scenario run --name paper-fig5 --model 3b --cpu-workers 2 \
             --tool-dist uniform:0.5,1.5",
        ))
        .unwrap();
        run(args("scenario run --name tool-storm --model 3b --cpu-workers 0")).unwrap();
        // --tool-dist without an active host model is refused, as is a
        // malformed distribution.
        assert!(run(args("scenario run --name paper-fig5 --tool-dist fixed")).is_err());
        assert!(run(args(
            "scenario run --name paper-fig5 --cpu-workers 2 --tool-dist warp:1"
        ))
        .is_err());
        // The flags reach `workflow run` and `cluster run` too.
        run(args(
            "workflow run --name supervisor-worker --tasks 2 --model 3b --cpu-workers 2",
        ))
        .unwrap();
        run(args(
            "cluster run --name tool-storm --replicas 2 --model 3b --cpu-workers 4",
        ))
        .unwrap();
    }

    #[test]
    fn scenario_sweep_cpu_workers_axis_smoke() {
        let dir = std::env::temp_dir().join("agentserve_cpu_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("cpu.json");
        let csv = dir.join("cpu.csv");
        run(args(&format!(
            "scenario sweep --scenario tool-storm --cpu-workers 2,8 --policy vllm \
             --model 3b --out {} --csv {}",
            json.to_str().unwrap(),
            csv.to_str().unwrap()
        )))
        .unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(report.req_str("axis").unwrap(), "cpu-workers");
        assert_eq!(report.req_arr("points").unwrap().len(), 2);
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        let header = csv_text.lines().next().unwrap();
        assert!(header.contains("tool_wait_p99_ms,host_util"));
        assert!(header.ends_with("replicas,load_cov,replica_us"));
        std::fs::remove_file(json).unwrap();
        std::fs::remove_file(csv).unwrap();
        // Registry sweeps refuse a would-be-dropped axis flag; two axes at
        // once and a zero worker count are loud errors; the host axis is a
        // scenario sweep, not a fleet grid.
        assert!(run(args("scenario sweep --name cpu-knee --cpu-workers 2,4")).is_err());
        assert!(run(args(
            "scenario sweep --scenario tool-storm --cpu-workers 2,4 --rates 1,2"
        ))
        .is_err());
        assert!(run(args("scenario sweep --scenario tool-storm --cpu-workers 0,2")).is_err());
        assert!(run(args("cluster sweep --scenario tool-storm --cpu-workers 2,4")).is_err());
    }

    #[test]
    fn stray_positional_rejected_outside_scenario() {
        assert!(run(args("bench vllm")).is_err(), "unknown bench action");
        assert!(run(args("figures 5")).is_err());
        assert!(run(args("analyze 7b")).is_err());
        assert!(run(args("serve now")).is_err());
        // Operand positionals are only for `bench diff`; everywhere else
        // they are loud errors, not silently ignored.
        assert!(run(args("scenario run paper-fig5 extra")).is_err());
        assert!(run(args("bench suite stray.json")).is_err());
        assert!(run(args("experiment run manifest.json")).is_err(), "--file is flag-only");
    }

    #[test]
    fn workflow_list_and_run_smoke() {
        run(args("workflow list")).unwrap();
        run(args("workflow run --name supervisor-worker --tasks 2 --model 3b")).unwrap();
        // Degenerate single-node workflow and a fan-out override.
        run(args("workflow run --name single-react --tasks 3 --model 3b")).unwrap();
        run(args(
            "workflow run --name supervisor-worker --tasks 2 --fan-out 2 --model 3b \
             --task-slo-ms 45000",
        ))
        .unwrap();
        assert!(run(args("workflow run --name no-such-workflow")).is_err());
        assert!(run(args("workflow run")).is_err(), "--name is required");
        assert!(run(args("workflow")).is_err());
        assert!(run(args("workflow frobnicate")).is_err());
        // Degree 0 is rejected by scenario validation.
        assert!(run(args("workflow run --name supervisor-worker --fan-out 0")).is_err());
    }

    #[test]
    fn exec_out_alias_dumps_the_event_log() {
        let dir = std::env::temp_dir().join("agentserve_exec_out");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exec.jsonl");
        let p = p.to_str().unwrap();
        run(args(&format!(
            "scenario run --name paper-fig5 --model 3b --exec-out {p}"
        )))
        .unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.lines().count() > 0, "exec log has one JSON event per line");
        assert!(text.contains("\"event\":\"arrival\""), "compact JSONL events");
        std::fs::remove_file(p).unwrap();
        // And on workflow runs, where it also carries task_done events.
        let p2 = dir.join("wf.jsonl");
        let p2 = p2.to_str().unwrap();
        run(args(&format!(
            "workflow run --name pipeline-chain --tasks 2 --model 3b --exec-out {p2}"
        )))
        .unwrap();
        let text = std::fs::read_to_string(p2).unwrap();
        assert!(text.contains("\"event\":\"task_done\""));
        std::fs::remove_file(p2).unwrap();
    }

    #[test]
    fn scenario_sweep_fan_out_axis_smoke() {
        let dir = std::env::temp_dir().join("agentserve_fan_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("fan.json");
        // Ad-hoc fan-out sweeps need a workflow-carrying base, which only
        // files (or the registry sweep) provide; exercise the file path.
        let sc = dir.join("wf-scenario.json");
        let scenario = crate::workload::Scenario {
            name: "fan-test".into(),
            ..crate::workflow::WorkflowLoad::new(
                crate::workflow::WorkflowSpec::by_name("supervisor-worker").unwrap(),
            )
            .carrier(2, 1.0)
        };
        scenario.save(&sc).unwrap();
        run(args(&format!(
            "scenario sweep --file {} --fan-outs 2,4 --policy vllm --model 3b --out {}",
            sc.to_str().unwrap(),
            json.to_str().unwrap()
        )))
        .unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(report.req_str("axis").unwrap(), "fan-out");
        assert_eq!(report.req_arr("points").unwrap().len(), 2);
        std::fs::remove_file(json).unwrap();
        std::fs::remove_file(sc).unwrap();
        // A fan-out grid over a plain base scenario is rejected.
        assert!(run(args(
            "scenario sweep --scenario paper-fig5 --fan-outs 2,4 --policy vllm"
        ))
        .is_err());
        // Registry sweeps refuse a would-be-dropped --fan-outs flag.
        assert!(run(args("scenario sweep --name fanout-knee --fan-outs 2,4")).is_err());
    }

    #[test]
    fn cluster_list_and_run_smoke() {
        run(args("cluster list")).unwrap();
        run(args("cluster run --name mixed-fleet --replicas 2 --model 3b")).unwrap();
        run(args(
            "cluster run --name mixed-fleet --replicas 3 --router round-robin --model 3b \
             --per-replica",
        ))
        .unwrap();
        assert!(run(args("cluster run --name no-such-scenario --replicas 2")).is_err());
        assert!(run(args("cluster run --name mixed-fleet --replicas 0")).is_err());
        assert!(run(args("cluster run --name mixed-fleet --router warp-speed")).is_err());
        assert!(run(args("cluster")).is_err());
        assert!(run(args("cluster frobnicate")).is_err());
    }

    #[test]
    fn cluster_sweep_smoke_and_artifacts() {
        let dir = std::env::temp_dir().join("agentserve_cluster_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("fleet.json");
        let csv = dir.join("fleet.csv");
        run(args(&format!(
            "cluster sweep --scenario mixed-fleet --replica-counts 1,2 --policy vllm \
             --model 3b --out {} --csv {}",
            json.to_str().unwrap(),
            csv.to_str().unwrap()
        )))
        .unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(report.req_str("axis").unwrap(), "replicas");
        assert_eq!(report.req_arr("points").unwrap().len(), 2);
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.lines().next().unwrap().ends_with("replicas,load_cov,replica_us"));
        assert_eq!(csv_text.lines().count(), 1 + 2);
        std::fs::remove_file(json).unwrap();
        std::fs::remove_file(csv).unwrap();
        // Flag validation: --name with would-be-dropped flags, non-fleet
        // registry names, and a missing axis are all loud errors.
        assert!(run(args("cluster sweep --name gpus-for-slo --replica-counts 1,2")).is_err());
        assert!(
            run(args("cluster sweep --name gpus-for-slo --router round-robin")).is_err(),
            "the registry sweep's router is baked in; --router must not be dropped"
        );
        assert!(run(args(
            "scenario sweep --scenario paper-fig5 --rates 1,2 --router round-robin"
        ))
        .is_err());
        // …and cluster sweep refuses scenario-sweep axis flags.
        assert!(run(args("cluster sweep --name gpus-for-slo --rates 0.5,1")).is_err());
        assert!(run(args(
            "cluster sweep --scenario mixed-fleet --replica-counts 1,2 --kv-blocks 640,65536"
        ))
        .is_err());
        assert!(run(args("cluster sweep --name kv-knee")).is_err(), "not a fleet sweep");
        assert!(run(args("cluster sweep --scenario mixed-fleet")).is_err());
        assert!(run(args("cluster sweep")).is_err());
        // The registry fleet sweep also resolves through `scenario sweep`
        // (it is just another sweep), and refuses dropped flags there too.
        assert!(run(args("scenario sweep --name gpus-for-slo --replica-counts 1,2")).is_err());
    }

    #[test]
    fn cluster_run_chaos_flags_smoke() {
        // Seeded crashes on an ordinary scenario; rate 0 is the baseline.
        run(args(
            "cluster run --name mixed-fleet --replicas 2 --fail-rate 6 --model 3b",
        ))
        .unwrap();
        run(args(
            "cluster run --name mixed-fleet --replicas 2 --fail-rate 0 --model 3b",
        ))
        .unwrap();
        // --restart-ms tunes an active process: OK alongside --fail-rate or
        // a chaos-carrying scenario, a loud error with neither.
        run(args(
            "cluster run --name mixed-fleet --replicas 2 --fail-rate 6 --restart-ms 500 \
             --model 3b",
        ))
        .unwrap();
        run(args(
            "cluster run --name failure-storm --replicas 2 --restart-ms 500 --model 3b",
        ))
        .unwrap();
        assert!(run(args(
            "cluster run --name mixed-fleet --replicas 2 --restart-ms 500"
        ))
        .is_err());
        assert!(run(args(
            "cluster run --name mixed-fleet --replicas 2 --fail-rate -1"
        ))
        .is_err());
        // An active process with a zero restart is rejected by validation.
        assert!(run(args(
            "cluster run --name mixed-fleet --replicas 2 --fail-rate 6 --restart-ms 0"
        ))
        .is_err());
    }

    #[test]
    fn cluster_sweep_chaos_axis_smoke() {
        let dir = std::env::temp_dir().join("agentserve_chaos_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("chaos.json");
        run(args(&format!(
            "cluster sweep --scenario mixed-fleet --chaos 0,6 --replicas 2 --policy vllm \
             --model 3b --out {}",
            json.to_str().unwrap()
        )))
        .unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(report.req_str("axis").unwrap(), "chaos");
        assert_eq!(report.req_arr("points").unwrap().len(), 2);
        std::fs::remove_file(json).unwrap();
        // Exactly one fleet axis at a time; registry names refuse ad-hoc
        // grids; the chaos axis lives under `cluster sweep`, not `scenario
        // sweep`.
        assert!(run(args(
            "cluster sweep --scenario mixed-fleet --chaos 0,6 --replica-counts 1,2"
        ))
        .is_err());
        assert!(run(args("cluster sweep --name chaos-resilience --chaos 1,2")).is_err());
        assert!(run(args("scenario sweep --scenario paper-fig5 --chaos 0,6")).is_err());
        assert!(run(args("scenario sweep --name chaos-resilience --chaos 0,6")).is_err());
        // Non-increasing and negative grids are rejected by validation.
        assert!(run(args("cluster sweep --scenario mixed-fleet --chaos 6,0")).is_err());
        assert!(run(args("cluster sweep --scenario mixed-fleet --chaos -1,2")).is_err());
    }

    #[test]
    fn cluster_run_autoscale_flags_smoke() {
        // The control plane on the registry tide scenario, default band.
        run(args("cluster run --name diurnal-burst --autoscale --model 3b")).unwrap();
        // An explicit band on an ordinary scenario.
        run(args(
            "cluster run --name mixed-fleet --autoscale --min-replicas 1 --max-replicas 3 \
             --model 3b",
        ))
        .unwrap();
        // Autoscale composes with seeded chaos.
        run(args(
            "cluster run --name mixed-fleet --autoscale --max-replicas 3 --fail-rate 6 \
             --model 3b",
        ))
        .unwrap();
        // --autoscale owns the fleet size: an explicit --replicas conflicts.
        assert!(run(args(
            "cluster run --name mixed-fleet --autoscale --replicas 2"
        ))
        .is_err());
        // Band flags without --autoscale are refused, not silently dropped.
        assert!(run(args("cluster run --name mixed-fleet --min-replicas 2")).is_err());
        assert!(run(args("cluster run --name mixed-fleet --max-replicas 3")).is_err());
        // An inverted band is a validation error.
        assert!(run(args(
            "cluster run --name mixed-fleet --autoscale --min-replicas 3 --max-replicas 1"
        ))
        .is_err());
        // The control plane has no meaning on a single GPU: `scenario run`
        // refuses the flags loudly.
        assert!(run(args("scenario run --name paper-fig5 --autoscale")).is_err());
        assert!(run(args("scenario run --name paper-fig5 --min-replicas 2")).is_err());
        assert!(run(args("scenario run --name paper-fig5 --max-replicas 4")).is_err());
    }

    #[test]
    fn cluster_sweep_autoscale_axis_smoke() {
        let dir = std::env::temp_dir().join("agentserve_autoscale_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("frontier.json");
        let csv = dir.join("frontier.csv");
        run(args(&format!(
            "cluster sweep --name autoscale-frontier --policy vllm --model 3b \
             --out {} --csv {}",
            json.to_str().unwrap(),
            csv.to_str().unwrap()
        )))
        .unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(report.req_str("axis").unwrap(), "autoscale");
        assert_eq!(report.req_arr("points").unwrap().len(), 3);
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.lines().next().unwrap().ends_with("replica_us"));
        std::fs::remove_file(json).unwrap();
        std::fs::remove_file(csv).unwrap();
        // The frontier sweep also resolves through `scenario sweep` (it is
        // just another sweep), and registry names still refuse ad-hoc flags.
        assert!(run(args("cluster sweep --name autoscale-frontier --replica-counts 1,2"))
            .is_err());
        assert!(run(args("scenario sweep --name autoscale-frontier --rates 1,2")).is_err());
    }

    #[test]
    fn workflow_run_fail_prob_smoke() {
        run(args(
            "workflow run --name supervisor-worker --tasks 2 --fail-prob 0.3 --model 3b",
        ))
        .unwrap();
        // Out-of-range probability and a spec with no tool node to attach
        // to are both validation errors.
        assert!(run(args(
            "workflow run --name supervisor-worker --tasks 2 --fail-prob 1.5"
        ))
        .is_err());
        assert!(run(args("workflow run --name debate --tasks 2 --fail-prob 0.3")).is_err());
    }

    #[test]
    fn events_path_splits_only_the_filename() {
        assert_eq!(events_path("ev.jsonl", "vllm"), "ev-vllm.jsonl");
        assert_eq!(events_path("ev", "vllm"), "ev-vllm");
        assert_eq!(events_path("runs.v2/ev", "vllm"), "runs.v2/ev-vllm");
        assert_eq!(events_path("runs.v2/ev.jsonl", "vllm"), "runs.v2/ev-vllm.jsonl");
        assert_eq!(events_path("a/b/.hidden", "x"), "a/b/.hidden-x");
        assert_eq!(policy_slug("llama.cpp"), "llama-cpp");
        assert_eq!(policy_slug("AgentServe"), "agentserve");
    }

    #[test]
    fn all_policies_events_get_distinct_files() {
        let dir = std::env::temp_dir().join("agentserve_scenario_events");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("ev.jsonl");
        let base = base.to_str().unwrap();
        run(args(&format!(
            "scenario run --name paper-fig5 --model 3b --all-policies --events {base}"
        )))
        .unwrap();
        for slug in ["agentserve", "sglang", "vllm", "llama-cpp"] {
            let p = dir.join(format!("ev-{slug}.jsonl"));
            assert!(p.exists(), "missing per-policy events file {p:?}");
            std::fs::remove_file(p).unwrap();
        }
    }

    #[test]
    fn scenario_record_then_replay_round_trips() {
        let dir = std::env::temp_dir().join("agentserve_scenario_cli");
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("burst.jsonl");
        let trace = trace.to_str().unwrap();
        run(args(&format!(
            "scenario record --name burst-storm --model 3b --out {trace}"
        )))
        .unwrap();
        run(args(&format!(
            "scenario replay --trace {trace} --model 3b --all-policies --verify"
        )))
        .unwrap();
    }

    #[test]
    fn scenario_file_with_config_overrides_runs() {
        use crate::workload::Scenario;
        let dir = std::env::temp_dir().join("agentserve_scenario_file");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("custom.json");
        // A registry scenario serialized to disk, plus engine overrides.
        let mut v = Scenario::by_name("mixed-fleet").unwrap().to_value();
        if let crate::util::json::Value::Obj(pairs) = &mut v {
            pairs.push((
                "config".to_string(),
                crate::util::json::parse(r#"{"engine": {"chunk_size": 128}}"#).unwrap(),
            ));
        }
        std::fs::write(&path, v.to_string_pretty()).unwrap();
        run(args(&format!(
            "scenario run --file {} --policy vllm",
            path.to_str().unwrap()
        )))
        .unwrap();
    }

    #[test]
    fn sweep_threads_flag_smoke() {
        // An explicit width runs; the report is byte-identical at any
        // width (locked by the sweep/experiment determinism tests), so
        // here we only exercise the CLI plumbing and the refusals.
        run(args(
            "scenario sweep --scenario paper-fig5 --rates 0.5,2 --policy vllm --model 3b \
             --threads 2",
        ))
        .unwrap();
        assert!(run(args(
            "scenario sweep --scenario paper-fig5 --rates 0.5,2 --policy vllm --threads 0"
        ))
        .is_err());
        assert!(run(args(
            "scenario sweep --scenario paper-fig5 --rates 0.5,2 --policy vllm --threads x"
        ))
        .is_err());
    }

    #[test]
    fn experiment_example_prints_and_validates() {
        run(args("experiment example")).unwrap();
        // The printed manifest round-trips through the parser.
        let v = crate::workload::ExperimentSpec::example_manifest();
        let spec = crate::workload::ExperimentSpec::from_value(&v).unwrap();
        spec.validate().unwrap();
    }

    #[test]
    fn experiment_run_smoke_and_artifacts() {
        let dir = std::env::temp_dir().join("agentserve_experiment_cli");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = dir.join("exp.json");
        std::fs::write(
            &manifest,
            r#"{
                "experiment": "cli-tiny",
                "scenario": {
                    "name": "cli-tiny-base",
                    "description": "6 open-loop ReAct sessions",
                    "arrivals": { "kind": "poisson", "rate_per_s": 1.0 },
                    "populations": [
                        { "name": "react", "workload": "react", "weight": 1.0 }
                    ],
                    "total_sessions": 6,
                    "n_agents": 6
                },
                "policies": ["agentserve"],
                "grid": { "rate": [0.5, 2.0], "replicas": [1, 2] }
            }"#,
        )
        .unwrap();
        let json = dir.join("exp-report.json");
        let csv = dir.join("exp-report.csv");
        run(args(&format!(
            "experiment run --file {} --model 3b --threads 2 --out {} --csv {}",
            manifest.to_str().unwrap(),
            json.to_str().unwrap(),
            csv.to_str().unwrap()
        )))
        .unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(report.req_str("experiment").unwrap(), "cli-tiny");
        assert_eq!(report.req_arr("cells").unwrap().len(), 4);
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with("cell,rate,replicas,overridden,policy,"));
        assert_eq!(csv_text.lines().count(), 1 + 4, "header + one row per cell×policy");
        std::fs::remove_file(json).unwrap();
        std::fs::remove_file(csv).unwrap();
        // Refusals: the manifest owns the policies; --file is required;
        // unknown/missing actions are loud.
        assert!(run(args(&format!(
            "experiment run --file {} --policy vllm",
            manifest.to_str().unwrap()
        )))
        .is_err());
        assert!(run(args(&format!(
            "experiment run --file {} --all-policies",
            manifest.to_str().unwrap()
        )))
        .is_err());
        assert!(run(args("experiment run")).is_err());
        assert!(run(args("experiment")).is_err());
        assert!(run(args("experiment frobnicate")).is_err());
        std::fs::remove_file(manifest).unwrap();
    }

    #[test]
    fn bench_diff_gates_on_regressions() {
        use crate::util::bench::{BenchPoint, BenchReport};
        let dir = std::env::temp_dir().join("agentserve_bench_diff_cli");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |wall: f64| BenchReport {
            label: "t".into(),
            model: "3b".into(),
            gpu: "a5000".into(),
            threads: 1,
            iters: 1,
            points: vec![BenchPoint {
                name: "sweep/x".into(),
                wall_ms: wall,
                min_ms: wall,
                metrics: vec![("slo_rate".into(), 0.9)],
            }],
        };
        let base = dir.join("base.json");
        let same = dir.join("same.json");
        let slow = dir.join("slow.json");
        mk(100.0).save(&base).unwrap();
        mk(110.0).save(&same).unwrap();
        mk(300.0).save(&slow).unwrap();
        let (base, same, slow) =
            (base.to_str().unwrap(), same.to_str().unwrap(), slow.to_str().unwrap());
        // Within default tolerance passes; a 3x slowdown fails; a huge
        // --tolerance waives it.
        run(args(&format!("bench diff {base} {same}"))).unwrap();
        assert!(run(args(&format!("bench diff {base} {slow}"))).is_err());
        run(args(&format!("bench diff {base} {slow} --tolerance 5"))).unwrap();
        // Arity and input validation.
        assert!(run(args(&format!("bench diff {base}"))).is_err());
        assert!(run(args(&format!("bench diff {base} {same} extra.json"))).is_err());
        assert!(run(args(&format!("bench diff {base} {same} --tolerance -1"))).is_err());
        assert!(run(args("bench diff missing-a.json missing-b.json")).is_err());
        for p in ["base.json", "same.json", "slow.json"] {
            std::fs::remove_file(dir.join(p)).unwrap();
        }
    }

    #[test]
    fn scenario_run_trace_out_writes_a_valid_chrome_trace() {
        let dir = std::env::temp_dir().join("agentserve_trace_out");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        let p = p.to_str().unwrap();
        run(args(&format!(
            "scenario run --name paper-fig5 --model 3b --trace-out {p}"
        )))
        .unwrap();
        let v = crate::util::json::parse(&std::fs::read_to_string(p).unwrap()).unwrap();
        assert_eq!(v.req_str("schema").unwrap(), "agentserve-trace-v1");
        assert!(!v.req_arr("traceEvents").unwrap().is_empty());
        assert!(
            v.get("phase_report").is_some(),
            "GPU-time attribution rides inside the trace artifact"
        );
        // The standalone validator accepts the artifact…
        run(args(&format!("trace validate --file {p}"))).unwrap();
        // …and rejects a mangled schema.
        std::fs::write(p, "{\"schema\":\"bogus\",\"traceEvents\":[]}").unwrap();
        assert!(run(args(&format!("trace validate --file {p}"))).is_err());
        std::fs::remove_file(p).unwrap();
        assert!(run(args("trace validate")).is_err(), "--file is required");
        assert!(run(args("trace frobnicate")).is_err());
        assert!(run(args("trace")).is_err());
    }

    #[test]
    fn probe_subcommand_dumps_json_and_csv() {
        let dir = std::env::temp_dir().join("agentserve_probe_cli");
        std::fs::create_dir_all(&dir).unwrap();
        let j = dir.join("p.json");
        let j = j.to_str().unwrap();
        run(args(&format!(
            "probe --name paper-fig5 --model 3b --interval-us 20000 --out {j}"
        )))
        .unwrap();
        let v = crate::util::json::parse(&std::fs::read_to_string(j).unwrap()).unwrap();
        assert_eq!(v.req_str("schema").unwrap(), "agentserve-probe-v1");
        let n = v.req_usize("n_samples").unwrap();
        assert!(n > 0, "a 20 ms grid over fig5 must sample");
        assert_eq!(v.req_arr("samples").unwrap().len(), n);
        // CSV by extension: header + one row per sample.
        let c = dir.join("p.csv");
        let c = c.to_str().unwrap();
        run(args(&format!(
            "probe --name paper-fig5 --model 3b --interval-us 20000 --out {c}"
        )))
        .unwrap();
        let csv = std::fs::read_to_string(c).unwrap();
        assert!(csv.lines().next().unwrap().starts_with("t_us,replica,"));
        assert_eq!(csv.lines().count(), 1 + n, "CSV rows conserve the sample count");
        // The fleet form samples every serving replica on the shared grid.
        run(args(&format!(
            "probe --name mixed-fleet --model 3b --replicas 2 --out {j}"
        )))
        .unwrap();
        let v = crate::util::json::parse(&std::fs::read_to_string(j).unwrap()).unwrap();
        assert!(v.req_usize("n_samples").unwrap() > 0);
        std::fs::remove_file(j).unwrap();
        std::fs::remove_file(c).unwrap();
        // Refusals: a router with no fleet, a sub-minimum grid, a missing
        // scenario, and a stray positional.
        assert!(run(args("probe --name paper-fig5 --router round-robin")).is_err());
        assert!(run(args("probe --name paper-fig5 --interval-us 10")).is_err());
        assert!(run(args("probe")).is_err());
        assert!(run(args("probe now")).is_err());
    }

    #[test]
    fn cluster_run_exec_out_dumps_replica_stamped_events() {
        let dir = std::env::temp_dir().join("agentserve_cluster_exec");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("fleet-exec.jsonl");
        let p = p.to_str().unwrap();
        run(args(&format!(
            "cluster run --name mixed-fleet --replicas 2 --model 3b --exec-out {p}"
        )))
        .unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.lines().count() > 0);
        let first = crate::util::json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.req_str("schema").unwrap(), "agentserve-exec-v1");
        assert!(first.get("replica").is_some());
        assert!(
            text.contains("\"replica\":1"),
            "the fleet merge stamps replica identity on routed events"
        );
        // The schema tag makes the exec log loudly un-replayable as a
        // workload trace.
        assert!(run(args(&format!("scenario replay --trace {p}"))).is_err());
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn cluster_run_trace_and_probe_out_smoke() {
        let dir = std::env::temp_dir().join("agentserve_cluster_obs");
        std::fs::create_dir_all(&dir).unwrap();
        let t = dir.join("fleet.json");
        let t = t.to_str().unwrap();
        let p = dir.join("fleet-probes.csv");
        let p = p.to_str().unwrap();
        // failure-storm: crash/restore instants land in the trace, and
        // spans from pre-crash incarnations survive the merge.
        run(args(&format!(
            "cluster run --name failure-storm --replicas 2 --model 3b \
             --trace-out {t} --probe-out {p} --probe-interval-us 100000"
        )))
        .unwrap();
        run(args(&format!("trace validate --file {t}"))).unwrap();
        let text = std::fs::read_to_string(t).unwrap();
        assert!(text.contains("\"what\": \"crash\""), "chaos instants ride the fleet trace");
        let csv = std::fs::read_to_string(p).unwrap();
        assert!(csv.lines().count() > 1);
        std::fs::remove_file(t).unwrap();
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn capture_flags_refused_where_inapplicable() {
        // Sweeps aggregate many runs; record/replay have their own
        // artifact — every capture flag is a loud error, never a silent
        // drop.
        assert!(run(args(
            "scenario sweep --scenario paper-fig5 --rates 1,2 --trace-out t.json"
        ))
        .is_err());
        assert!(run(args(
            "scenario sweep --scenario paper-fig5 --rates 1,2 --exec-out e.jsonl"
        ))
        .is_err());
        assert!(run(args(
            "cluster sweep --scenario mixed-fleet --replica-counts 1,2 --probe-out p.json"
        ))
        .is_err());
        assert!(run(args(
            "scenario record --name burst-storm --out t.jsonl --trace-out x.json"
        ))
        .is_err());
        // --probe-interval-us without --probe-out would do nothing.
        assert!(run(args(
            "scenario run --name paper-fig5 --probe-interval-us 50000"
        ))
        .is_err());
    }

    #[test]
    fn trace_record_then_replay_matches() {
        let dir = std::env::temp_dir().join("agentserve_cli_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        let p = p.to_str().unwrap();
        run(args(&format!(
            "bench --model 3b --agents 3 --sessions 1 --save-trace {p}"
        )))
        .unwrap();
        run(args(&format!(
            "bench --model 3b --agents 3 --sessions 1 --replay-trace {p} --policy vllm"
        )))
        .unwrap();
    }
}
