//! `agentserve` CLI — leader entrypoint.
//!
//! Subcommands:
//! - `bench`    one simulated serving benchmark (policy x model x GPU x N)
//! - `scenario` the workload engine: list|run|record|replay|sweep
//! - `figures`  regenerate the paper's tables/figures
//! - `analyze`  competitive-ratio bounds (Theorem 1 / Corollary 2)
//! - `serve`    end-to-end demo on the real PJRT engine

fn main() -> anyhow::Result<()> {
    let args = agentserve::util::cli::Args::from_env()?;
    agentserve::server::run(args)
}
