//! Cross-layer interaction matrix (tier-1).
//!
//! Each optional layer — bounded KV pool, workflow DAG, chaos faults,
//! multi-replica fleet, autoscale control plane — is locked in isolation
//! by its own suite. This suite locks their *compositions*: every stack of
//! layers must still terminate, conserve the scripted decode-token budget
//! (exactly without crashes, up to `redecoded_tokens` with them), lose no
//! session, respect the autoscale band, and rerun byte-identically from
//! one `(config, scenario, seed)` tuple.

use agentserve::cluster::run_cluster_fast;
use agentserve::config::{
    AutoscaleConfig, ChaosConfig, FaultEvent, FaultKind, KvConfig, RouterPolicy,
};
use agentserve::engine::Policy;
use agentserve::workload::Scenario;

mod common;
use common::{cfg, scripted_tokens, wf_scenario};

/// A hot controller that fires on any nonzero load — makes the autoscale
/// layer participate deterministically in every composition below.
fn hot_autoscale(max_replicas: usize) -> AutoscaleConfig {
    AutoscaleConfig {
        up_thresh: 0.5,
        down_thresh: 0.1,
        ..AutoscaleConfig::banded(1, max_replicas)
    }
}

#[test]
fn bounded_kv_workflow_crash_fleet_conserves_and_reruns() {
    // Three layers at once: a bounded shared-prefix pool, a supervisor/
    // worker DAG, and a scripted replica crash on a 2-replica fleet. The
    // crash forces re-routes and recomputes; joins still resolve, the pool
    // still admits everyone, and the token ledger closes exactly.
    let cfg = cfg();
    let sc = Scenario {
        kv: Some(KvConfig { num_blocks: 2048, block_size: 16, prefix_sharing: true }),
        chaos: Some(ChaosConfig {
            events: vec![FaultEvent { at_us: 300_000, replica: 0, kind: FaultKind::Crash }],
            mtbf_us: 0,
            restart_us: 2_000_000,
        }),
        ..wf_scenario("supervisor-worker", 4, 0.5)
    };
    sc.validate().unwrap();
    let expected = scripted_tokens(&cfg, &sc, 7);
    for router in [RouterPolicy::RoundRobin, RouterPolicy::CacheAware] {
        let out =
            run_cluster_fast(&cfg, Policy::AgentServe(Default::default()), &sc, 2, router, 7)
                .unwrap();
        let chaos = out.report.chaos.as_ref().expect("scripted crash reports chaos stats");
        assert_eq!(chaos.crashes, 1, "{router}");
        assert_eq!(
            out.report.completed_sessions, out.report.sessions,
            "{router}: crashed sessions must be re-routed, not dropped"
        );
        assert_eq!(
            out.report.total_tokens,
            expected + chaos.redecoded_tokens,
            "{router}: decode tokens conserved up to crash-forced recompute"
        );
        assert!(out.report.kv_present, "{router}: the bounded pool rode the fleet");
        let wf = out.report.workflow.as_ref().expect("workflow metrics ride the fleet");
        assert_eq!(wf.tasks, 4, "{router}");
        assert_eq!(wf.completed_tasks, 4, "{router}");
        let again =
            run_cluster_fast(&cfg, Policy::AgentServe(Default::default()), &sc, 2, router, 7)
                .unwrap();
        assert_eq!(
            out.report.to_value().to_string(),
            again.report.to_value().to_string(),
            "{router}: the three-layer stack must rerun byte-identically"
        );
    }
}

#[test]
fn full_stack_kv_workflow_autoscale_conserves_exactly() {
    // Bounded KV × workflow DAG × control plane, no faults: scaling must be
    // invisible to the ledger — every scripted token exactly once, every
    // task complete, fleet size inside the band.
    let cfg = cfg();
    let sc = Scenario {
        kv: Some(KvConfig { num_blocks: 4096, block_size: 16, prefix_sharing: true }),
        autoscale: Some(hot_autoscale(3)),
        ..wf_scenario("supervisor-worker", 6, 2.0)
    };
    sc.validate().unwrap();
    let expected = scripted_tokens(&cfg, &sc, 7);
    let run = || {
        run_cluster_fast(
            &cfg,
            Policy::AgentServe(Default::default()),
            &sc,
            1,
            RouterPolicy::CacheAware,
            7,
        )
        .unwrap()
    };
    let out = run();
    assert_eq!(out.report.completed_sessions, out.report.sessions);
    assert_eq!(out.report.total_tokens, expected, "no chaos, no recompute: exact conservation");
    assert!(out.report.kv_present);
    let wf = out.report.workflow.as_ref().expect("workflow metrics present");
    assert_eq!(wf.completed_tasks, 6);
    let auto = out.report.autoscale.as_ref().expect("a hot threshold drives the controller");
    assert!(auto.scale_ups > 0, "load above 0.5 per replica must boot capacity");
    assert!(auto.peak_replicas <= 3, "peak {} exceeded the band", auto.peak_replicas);
    assert!(auto.replica_us > 0);
    let again = run();
    assert_eq!(
        out.report.to_value().to_string(),
        again.report.to_value().to_string(),
        "the full stack must rerun byte-identically"
    );
}

#[test]
fn autoscale_rides_out_a_crash_storm() {
    // Chaos × autoscale on the open-loop mix: a scripted crash plus seeded
    // crashes (mtbf 10 s) while a hot controller scales the fleet. Both
    // stats blocks report, no session is lost, and the ledger closes up to
    // the crash-forced recompute.
    let cfg = cfg();
    let sc = Scenario {
        chaos: Some(ChaosConfig {
            events: vec![FaultEvent { at_us: 200_000, replica: 0, kind: FaultKind::Crash }],
            mtbf_us: 10_000_000,
            restart_us: 2_000_000,
        }),
        autoscale: Some(hot_autoscale(4)),
        ..Scenario::by_name("mixed-fleet").unwrap()
    };
    sc.validate().unwrap();
    let expected = scripted_tokens(&cfg, &sc, 7);
    let run = || {
        run_cluster_fast(
            &cfg,
            Policy::AgentServe(Default::default()),
            &sc,
            2,
            RouterPolicy::LeastOutstanding,
            7,
        )
        .unwrap()
    };
    let out = run();
    let chaos = out.report.chaos.as_ref().expect("crashes report the chaos block");
    assert!(chaos.crashes >= 1);
    let auto = out.report.autoscale.as_ref().expect("the hot controller reports its block");
    assert!(auto.scale_ups > 0);
    assert!(auto.peak_replicas <= 4);
    assert_eq!(out.report.completed_sessions, out.report.sessions, "no session lost");
    assert_eq!(
        out.report.total_tokens,
        expected + chaos.redecoded_tokens,
        "conserved up to crash-forced recompute"
    );
    let again = run();
    assert_eq!(
        out.report.to_value().to_string(),
        again.report.to_value().to_string(),
        "chaos x autoscale must rerun byte-identically"
    );
}

#[test]
fn failure_storm_with_autoscaler_reports_both_blocks() {
    // The registry chaos scenario (seeded crashes + flaky tools over a
    // workflow) with the control plane attached: the run terminates, every
    // session completes somewhere, and the report carries the chaos and
    // autoscale blocks side by side.
    let cfg = cfg();
    let sc = Scenario {
        autoscale: Some(hot_autoscale(4)),
        ..Scenario::by_name("failure-storm").unwrap()
    };
    sc.validate().unwrap();
    let expected = scripted_tokens(&cfg, &sc, 7);
    let out = run_cluster_fast(
        &cfg,
        Policy::AgentServe(Default::default()),
        &sc,
        2,
        RouterPolicy::CacheAware,
        7,
    )
    .unwrap();
    let chaos = out.report.chaos.as_ref().expect("failure-storm reports chaos");
    let auto = out.report.autoscale.as_ref().expect("the controller reports beside it");
    assert!(auto.scale_ups > 0);
    assert!(auto.peak_replicas <= 4);
    assert_eq!(
        out.report.completed_sessions, out.report.sessions,
        "crashes + retries + scaling must never wedge or drop a session"
    );
    assert_eq!(
        out.report.total_tokens,
        expected + chaos.redecoded_tokens,
        "tool retries delay but never mint tokens; crashes only recompute"
    );
    let wf = out.report.workflow.as_ref().expect("failure-storm carries a workflow");
    assert_eq!(wf.tasks, 12);
}
