//! KV-cache subsystem under churn (tier-1): allocator + radix + session
//! invariants across randomized admit/prefill/decode/finish sequences,
//! deterministic eviction/preemption under pressure, and the memory-bound
//! registry scenarios' acceptance properties (nonzero evictions and
//! preemptions on `memory-pressure`, >0.9 radix hit rate on
//! `shared-prefix-fleet`, a detected memory knee on a kv-blocks sweep).

use agentserve::config::KvConfig;
use agentserve::engine::{run_scenario_fast, Policy};
use agentserve::kvcache::{BlockAllocator, RadixPrefixCache, SessionCache};
use agentserve::util::rng::Rng;
use agentserve::workload::{run_sweep, Scenario, SweepAxis, SweepSpec};

mod common;
use common::cfg;

// ---------------------------------------------------------------------------
// Property: allocator + radix + session caches preserve every invariant
// under random admit / prefill / decode / finish / evict sequences.
// ---------------------------------------------------------------------------

/// Model of one live session in the property driver.
struct Live {
    cache: SessionCache,
    prompt: Vec<u32>,
}

/// Total references the model expects the allocator to hold: one per block
/// per session list entry, plus one per block pinned by the radix tree.
fn expected_refs(sessions: &[Option<Live>], radix: &RadixPrefixCache) -> usize {
    sessions
        .iter()
        .flatten()
        .map(|l| l.cache.blocks().len())
        .sum::<usize>()
        + radix.cached_blocks()
}

fn total_refs(alloc: &BlockAllocator) -> usize {
    (0..alloc.num_blocks() as u32).map(|b| alloc.ref_count(b) as usize).sum()
}

#[test]
fn prop_kv_trio_invariants_under_churn() {
    let bs = 16usize;
    for seed in 0..25u64 {
        let mut rng = Rng::seed_from_u64(42_000 + seed);
        let pool = 192 + (rng.next_u64() % 256) as usize;
        let mut alloc = BlockAllocator::new(pool, bs);
        let mut radix = RadixPrefixCache::new();
        let n_slots = 6usize;
        let mut sessions: Vec<Option<Live>> = (0..n_slots).map(|_| None).collect();
        // A handful of shared "templates" so lookups actually hit.
        let templates: Vec<Vec<u32>> = (0..3)
            .map(|t| (0..(bs as u32 * (4 + t))).map(|i| i * 3 + t).collect())
            .collect();

        for step in 0..400 {
            let slot = (rng.next_u64() % n_slots as u64) as usize;
            match rng.next_u64() % 5 {
                // Admit: radix lookup + adopt + begin a cold prefill.
                0 if sessions[slot].is_none() => {
                    let prompt = templates[(rng.next_u64() % 3) as usize].clone();
                    let (matched, leased) = radix.lookup(&prompt, &mut alloc);
                    let uncached = prompt.len() - matched;
                    if alloc.free_blocks() >= alloc.blocks_for(uncached) {
                        let mut cache = SessionCache::new();
                        cache.adopt_prefix(leased, &prompt, matched);
                        cache
                            .begin_prefill(&prompt[matched..], &mut alloc)
                            .expect("headroom checked");
                        sessions[slot] = Some(Live { cache, prompt });
                    } else {
                        for b in leased {
                            alloc.release(b).unwrap();
                        }
                    }
                }
                // Complete the prefill and index the prompt for sharing.
                1 => {
                    if let Some(l) = &mut sessions[slot] {
                        l.cache.complete_prefill();
                        if l.cache.committed_tokens() >= l.prompt.len() {
                            radix.insert(&l.prompt, l.cache.blocks(), &mut alloc);
                        }
                    }
                }
                // Decode one token (only on committed, unfenced caches).
                2 => {
                    if let Some(l) = &mut sessions[slot] {
                        if l.cache.decode_ready() && alloc.free_blocks() > 0 {
                            l.cache.append_decoded(7, &mut alloc).expect("headroom");
                        }
                    }
                }
                // Finish: release everything the session holds.
                3 => {
                    if let Some(mut l) = sessions[slot].take() {
                        l.cache.complete_prefill();
                        l.cache.release_all(&mut alloc).unwrap();
                    }
                }
                // Pressure: evict a few LRU radix leaves.
                _ => {
                    radix.evict_lru(1 + (rng.next_u64() % 3) as usize, &mut alloc);
                }
            }
            alloc
                .check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
            assert_eq!(
                total_refs(&alloc),
                expected_refs(&sessions, &radix),
                "seed {seed} step {step}: reference-count conservation"
            );
        }
        // Drain: finish every session, evict the whole tree — no leaks.
        for slot in 0..n_slots {
            if let Some(mut l) = sessions[slot].take() {
                l.cache.complete_prefill();
                l.cache.release_all(&mut alloc).unwrap();
            }
        }
        while radix.evict_lru(usize::MAX, &mut alloc) > 0 {}
        assert_eq!(alloc.used_blocks(), 0, "seed {seed}: blocks leaked");
        alloc.check_invariants().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Eviction/preemption under pressure: deterministic, conservative, nonzero.
// ---------------------------------------------------------------------------

/// A scaled-down memory-pressure fleet (same shape as the registry
/// scenario, 300 sessions instead of 2,000) — cheap enough to run under
/// every paper policy.
fn scaled_pressure_fleet() -> Scenario {
    Scenario {
        kv: Some(KvConfig { num_blocks: 1024, block_size: 16, prefix_sharing: true }),
        ..common::open_loop("pressure-300", 8.0, 300)
    }
}

#[test]
fn eviction_under_pressure_is_deterministic() {
    let cfg = cfg();
    let sc = scaled_pressure_fleet();
    sc.validate().unwrap();
    let expected = sc.instantiate(cfg.model.kind, 7).trace.total_decode_tokens();
    for policy in [Policy::AgentServe(Default::default()), Policy::Vllm] {
        let a = run_scenario_fast(&cfg, policy, &sc, 7);
        let b = run_scenario_fast(&cfg, policy, &sc, 7);
        assert_eq!(a.report.completed_sessions, 300, "{}", policy.name());
        assert_eq!(a.report.total_tokens, expected, "{}", policy.name());
        assert_eq!(
            a.report.to_value().to_string(),
            b.report.to_value().to_string(),
            "{}: pressure runs must be byte-deterministic",
            policy.name()
        );
        let (ka, kb) = (a.kv.expect("paged"), b.kv.expect("paged"));
        assert_eq!(ka.evictions, kb.evictions, "{}", policy.name());
        assert_eq!(ka.preemptions, kb.preemptions, "{}", policy.name());
        assert_eq!(ka.peak_blocks, kb.peak_blocks, "{}", policy.name());
        assert!(
            ka.evictions > 0 && ka.preemptions > 0,
            "{}: a 300-agent burst on a 1,024-block pool must evict ({}) and preempt ({})",
            policy.name(),
            ka.evictions,
            ka.preemptions
        );
        assert!(ka.peak_blocks <= 1024, "{}", policy.name());
    }
}

#[test]
fn memory_pressure_registry_scenario_shows_pressure() {
    // Acceptance: with its shipped constrained pool, the 2,000-agent
    // `memory-pressure` scenario reports nonzero evictions and preemptions,
    // deterministically, while conserving every scripted decode token.
    let cfg = cfg();
    let sc = Scenario::by_name("memory-pressure").unwrap();
    let expected = sc.instantiate(cfg.model.kind, 7).trace.total_decode_tokens();
    let out = run_scenario_fast(&cfg, Policy::AgentServe(Default::default()), &sc, 7);
    assert_eq!(out.report.completed_sessions, sc.total_sessions);
    assert_eq!(out.report.total_tokens, expected);
    let kv = out.kv.expect("memory-pressure runs the paged path");
    assert!(kv.evictions > 0, "evictions {}", kv.evictions);
    assert!(kv.preemptions > 0, "preemptions {}", kv.preemptions);
    assert!(kv.stalls.n > 0, "stalls {}", kv.stalls.n);
    assert!(kv.peak_blocks <= 2048, "peak {} within the pool", kv.peak_blocks);
    let again = run_scenario_fast(&cfg, Policy::AgentServe(Default::default()), &sc, 7);
    assert_eq!(
        out.report.to_value().to_string(),
        again.report.to_value().to_string(),
        "same seed must reproduce the pressure run byte-for-byte"
    );
    assert_eq!(kv.preemptions, again.kv.expect("paged").preemptions);
}

#[test]
fn shared_prefix_fleet_reaches_high_radix_hit_rate() {
    // Acceptance: the shared-prefix fleet's cold prefills overwhelmingly
    // hit the radix cache (>0.9 of looked-up tokens), collapsing cold cost.
    let cfg = cfg();
    let sc = Scenario::by_name("shared-prefix-fleet").unwrap();
    let out = run_scenario_fast(&cfg, Policy::AgentServe(Default::default()), &sc, 7);
    assert_eq!(out.report.completed_sessions, sc.total_sessions);
    let kv = out.kv.expect("paged path");
    assert!(
        kv.radix_hit_rate() > 0.9,
        "hit rate {:.3} (hit {} / miss {})",
        kv.radix_hit_rate(),
        kv.radix_hit_tokens,
        kv.radix_miss_tokens
    );
    assert_eq!(kv.preemptions, 0, "the generous pool must not preempt");
    // And the shared fleet's measured cold fraction collapses relative to
    // the same fleet without sharing.
    let mut unshared = sc.clone();
    unshared.kv = Some(KvConfig { num_blocks: 65_536, block_size: 16, prefix_sharing: false });
    let base = run_scenario_fast(&cfg, Policy::AgentServe(Default::default()), &unshared, 7);
    assert!(
        out.eta_cold < base.eta_cold * 0.5,
        "radix reuse must at least halve the cold work fraction ({} vs {})",
        out.eta_cold,
        base.eta_cold
    );
}

// ---------------------------------------------------------------------------
// kv-blocks sweep: the memory knee is detected.
// ---------------------------------------------------------------------------

#[test]
fn kv_blocks_sweep_detects_a_memory_knee() {
    let cfg = cfg();
    let spec = SweepSpec {
        name: "knee-test".into(),
        description: String::new(),
        base: common::open_loop("knee-fleet", 4.0, 20),
        axis: SweepAxis::KvBlocks(vec![640, 262_144]),
    };
    spec.validate().unwrap();
    let policies = [Policy::AgentServe(Default::default()), Policy::LlamaCpp];
    let report = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    assert_eq!(report.axis, "kv-blocks");
    // A ~2.5-session pool facing 20 near-simultaneous agents must blow the
    // TTFT SLO, so at least one policy's memory knee is detected.
    assert!(
        report.knees.iter().any(|(_, knee)| knee.is_some()),
        "knees: {:?}",
        report.knees
    );
    // Memory monotonicity: the starved point's tail TTFT dominates the
    // effectively-unbounded point's, under every policy.
    for (pi, policy) in policies.iter().enumerate() {
        let starved = &report.points[0].per_policy[pi];
        let ample = &report.points[1].per_policy[pi];
        assert!(
            starved.ttft_p99 > ample.ttft_p99,
            "{}: {} vs {}",
            policy.name(),
            starved.ttft_p99,
            ample.ttft_p99
        );
        assert_eq!(starved.completed, 20, "{}", policy.name());
        assert_eq!(ample.completed, 20, "{}", policy.name());
    }
    // The CSV carries the memory columns. (`contains`, not `ends_with`:
    // later layers appended workflow and fleet columns after these.)
    let csv = report.to_csv();
    assert!(csv.lines().next().unwrap().contains("stall_p99_ms"));
    assert_eq!(csv.lines().count(), 1 + 2 * policies.len());
}
