//! Host execution-model integration tests (tier-1).
//!
//! The contracts this suite locks:
//! - **Inert purity**: an absent *or inert* host config (`cpu_workers == 0`)
//!   keeps every run on the exact legacy tool-latency path — reports are
//!   byte-identical under the whole paper policy lineup and every router.
//! - **Contention ordering**: on coupled seeds over the `tool-storm`
//!   scenario, 2 CPU workers queue tool calls and show strictly worse p99
//!   task latency than 8 workers — the capacity knee the `cpu-knee` sweep
//!   maps as data.
//! - **Determinism**: host queue waits (including log-normal service
//!   draws) are a pure function of `(seed, scenario, config)`, and tokens
//!   are conserved under contention — queueing delays work, never drops
//!   or duplicates it.

use agentserve::cluster::run_cluster_fast;
use agentserve::config::{HostConfig, RouterPolicy};
use agentserve::engine::{run_scenario_fast, Policy};
use agentserve::workload::{run_sweep, Scenario, SweepSpec};

mod common;
use common::{cfg, scripted_tokens};

#[test]
fn inert_host_config_keeps_the_legacy_bytes_under_every_policy_and_router() {
    // `host: None` and an attached-but-inert config (0 workers) must both
    // take the legacy path: same report bytes, no host block.
    let cfg = cfg();
    let plain = Scenario::by_name("mixed-fleet").unwrap();
    let inert = Scenario { host: Some(HostConfig::default()), ..plain.clone() };
    for policy in Policy::paper_lineup() {
        for router in RouterPolicy::ALL {
            let a = run_cluster_fast(&cfg, policy, &plain, 2, router, 7).unwrap();
            let b = run_cluster_fast(&cfg, policy, &inert, 2, router, 7).unwrap();
            let tag = format!("{}/{}", policy.name(), router);
            assert!(a.report.host.is_none(), "{tag}: no host block without workers");
            assert_eq!(
                a.report.to_value().to_string(),
                b.report.to_value().to_string(),
                "{tag}: an inert host config must not perturb a single byte"
            );
        }
    }
    // Same contract on the single-GPU path, including a workflow carrier.
    for name in ["paper-fig5", "burst-storm"] {
        let plain = Scenario::by_name(name).unwrap();
        let inert = Scenario { host: Some(HostConfig::default()), ..plain.clone() };
        for policy in Policy::paper_lineup() {
            let a = run_scenario_fast(&cfg, policy, &plain, 7);
            let b = run_scenario_fast(&cfg, policy, &inert, 7);
            assert!(a.host.is_none(), "{name}: no host report without workers");
            assert_eq!(
                a.report.to_value().to_string(),
                b.report.to_value().to_string(),
                "{name}/{}: inert host must keep the legacy bytes",
                policy.name()
            );
        }
    }
}

#[test]
fn fewer_cpu_workers_strictly_worsen_tail_task_latency() {
    // tool-storm: 12-wide worker fan-out resolving into bursts of tool
    // calls. Coupled seeds mean both runs issue the identical call stream;
    // only the sandbox capacity differs.
    let cfg = cfg();
    let base = Scenario::by_name("tool-storm").unwrap();
    let with_workers = |n: usize| Scenario {
        host: Some(HostConfig { cpu_workers: n, ..base.host.clone().unwrap() }),
        ..base.clone()
    };
    let policy = Policy::AgentServe(Default::default());
    let starved = run_scenario_fast(&cfg, policy, &with_workers(2), 7);
    let ample = run_scenario_fast(&cfg, policy, &with_workers(8), 7);
    let (hs, ha) = (
        starved.host.as_ref().expect("active host => report"),
        ample.host.as_ref().expect("active host => report"),
    );
    assert_eq!(hs.calls, ha.calls, "coupled seeds: the same tool-call stream");
    assert!(hs.calls > 0, "the storm must actually issue tool calls");
    assert!(hs.queued_calls > 0, "12-wide bursts on 2 workers must queue");
    assert!(
        hs.tool_wait_p99_ms > ha.tool_wait_p99_ms,
        "2 workers must wait longer at the tail than 8 ({:.1} ms vs {:.1} ms)",
        hs.tool_wait_p99_ms,
        ha.tool_wait_p99_ms
    );
    let (ws, wa) = (
        starved.workflow.as_ref().expect("tool-storm is a workflow scenario"),
        ample.workflow.as_ref().expect("tool-storm is a workflow scenario"),
    );
    assert!(
        ws.makespan.p99 > wa.makespan.p99,
        "strictly worse p99 task latency at 2 workers ({:.1} ms vs {:.1} ms)",
        ws.makespan.p99,
        wa.makespan.p99
    );
    // Token conservation under contention: queueing delays work, it never
    // drops or duplicates any scripted decode token.
    let expected = scripted_tokens(&cfg, &base, 7);
    assert_eq!(starved.report.total_tokens, expected);
    assert_eq!(ample.report.total_tokens, expected);
    assert_eq!(starved.report.completed_sessions, ample.report.completed_sessions);
}

#[test]
fn host_waits_are_a_pure_function_of_seed_scenario_and_config() {
    // slow-sandbox draws log-normal service scalings from the dedicated
    // host stream: reruns are byte-identical, a new seed is a new run.
    let cfg = cfg();
    let sc = Scenario::by_name("slow-sandbox").unwrap();
    let policy = Policy::Vllm;
    let a = run_scenario_fast(&cfg, policy, &sc, 7);
    let b = run_scenario_fast(&cfg, policy, &sc, 7);
    assert_eq!(
        a.report.to_value().to_string(),
        b.report.to_value().to_string(),
        "same (scenario, seed) must serialize byte-identically"
    );
    let (ha, hb) = (a.host.as_ref().unwrap(), b.host.as_ref().unwrap());
    assert_eq!(ha.to_value().to_string(), hb.to_value().to_string());
    assert!(ha.calls > 0);
    let c = run_scenario_fast(&cfg, policy, &sc, 8);
    let hc = c.host.as_ref().unwrap();
    assert_ne!(
        (ha.to_value().to_string(), a.report.to_value().to_string()),
        (hc.to_value().to_string(), c.report.to_value().to_string()),
        "a new seed must be a new run"
    );
}

#[test]
fn fleet_host_reports_merge_raw_samples_and_rerun_byte_identically() {
    let cfg = cfg();
    let sc = Scenario::by_name("tool-storm").unwrap();
    let policy = Policy::AgentServe(Default::default());
    let a = run_cluster_fast(&cfg, policy, &sc, 2, RouterPolicy::CacheAware, 7).unwrap();
    let b = run_cluster_fast(&cfg, policy, &sc, 2, RouterPolicy::CacheAware, 7).unwrap();
    assert_eq!(
        a.report.to_value().to_string(),
        b.report.to_value().to_string(),
        "fleet host accounting must rerun byte-identically"
    );
    let h = a.report.host.as_ref().expect("active host => fleet report block");
    assert_eq!(h.cpu_workers, 2);
    assert!(h.calls > 0);
    assert!(h.utilization > 0.0 && h.utilization <= 1.0);
    assert!(a.report.to_value().to_string().contains("\"host\""));
    // Sessions and scripted tokens survive routing through the host queue.
    assert_eq!(a.report.completed_sessions, a.report.sessions);
    assert_eq!(a.report.total_tokens, scripted_tokens(&cfg, &sc, 7));
}

#[test]
fn cpu_knee_sweep_reports_the_smallest_compliant_worker_count() {
    let cfg = cfg();
    let spec = SweepSpec::by_name("cpu-knee").unwrap();
    spec.validate().unwrap();
    let policies = [Policy::AgentServe(Default::default())];
    let report = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    let again = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    assert_eq!(
        report.to_value().to_string(),
        again.to_value().to_string(),
        "the capacity sweep must rerun byte-identically"
    );
    assert_eq!(report.axis, "cpu-workers");
    assert_eq!(report.points.len(), 3);
    for pt in &report.points {
        let pp = &pt.per_policy[0];
        assert!(pp.host_util > 0.0, "every grid point runs an active host");
        assert!(pp.makespan_p99_ms > 0.0, "the base carries a workflow");
    }
    // The acceptance knee: some worker count in {2, 4, 8} meets the task
    // SLO, and the knee is the smallest one that does.
    let (_, knee) = &report.knees[0];
    let knee = knee.expect("a finite cpu-knee within the grid");
    assert!(
        [2.0, 4.0, 8.0].contains(&knee),
        "knee must be a grid value (got {knee})"
    );
    let first_ok = report
        .points
        .iter()
        .find(|pt| pt.per_policy[0].makespan_p99_ms <= cfg.slo.task_ms)
        .expect("the knee implies a compliant point");
    assert_eq!(first_ok.axis_value, knee, "FirstCompliant: smallest compliant worker count");
    // The host columns ride both artifact forms.
    assert!(report.to_csv().lines().next().unwrap().contains("tool_wait_p99_ms,host_util"));
    assert!(report.to_value().to_string().contains("\"tool_wait_p99_ms\""));
}
