//! Scenario-engine regression suite (tier-1): every built-in scenario
//! completes under every paper policy, identical `(Config, Scenario, seed)`
//! runs produce byte-identical reports, recorded traces replay
//! deterministically, and the checked-in golden trace reproduces its pinned
//! report snapshot exactly.

use agentserve::config::{Config, GpuKind, ModelKind};
use agentserve::engine::{
    record_scenario_trace, run_scenario, run_scenario_recorded, run_sim_trace, ExecEventKind,
    Policy,
};
use agentserve::metrics::RunReport;
use agentserve::workload::{Scenario, Trace};

fn cfg() -> Config {
    Config::preset(ModelKind::Qwen3B, GpuKind::A5000)
}

/// Byte-exact comparison key: the deterministic JSON summary.
fn key(r: &RunReport) -> String {
    r.to_value().to_string()
}

#[test]
fn every_builtin_scenario_completes_under_every_policy() {
    let cfg = cfg();
    for scenario in Scenario::registry() {
        scenario.validate().unwrap();
        if scenario.kv.is_some() {
            // The memory-bound registry scenarios (thousands of sessions
            // under deliberate KV pressure) are exercised separately in
            // rust/tests/kvcache_churn.rs — running them under all four
            // policies here would dominate the tier-1 suite's runtime.
            continue;
        }
        let expected = scenario
            .instantiate(cfg.model.kind, 7)
            .trace
            .total_decode_tokens();
        for policy in Policy::paper_lineup() {
            let out = run_scenario(&cfg, policy, &scenario, 7);
            assert_eq!(
                out.report.completed_sessions,
                scenario.total_sessions,
                "{}/{} must complete every session",
                scenario.name,
                policy.name()
            );
            assert_eq!(
                out.report.total_tokens,
                expected,
                "{}/{} must conserve scripted decode tokens",
                scenario.name,
                policy.name()
            );
        }
    }
}

#[test]
fn same_seed_identical_reports_across_policy_lineup() {
    let cfg = cfg();
    // One closed-loop and one open-loop scenario exercise both arrival paths.
    for name in ["paper-fig5", "mixed-fleet"] {
        let scenario = Scenario::by_name(name).unwrap();
        for policy in Policy::paper_lineup() {
            let a = run_scenario(&cfg, policy, &scenario, 41);
            let b = run_scenario(&cfg, policy, &scenario, 41);
            assert_eq!(
                key(&a.report),
                key(&b.report),
                "{name}/{}: same (Config, Scenario, seed) must be byte-identical",
                policy.name()
            );
            assert_eq!(a.arrivals_us, b.arrivals_us);
            let c = run_scenario(&cfg, policy, &scenario, 42);
            assert_ne!(
                key(&a.report),
                key(&c.report),
                "{name}/{}: different seeds must differ",
                policy.name()
            );
        }
    }
}

#[test]
fn recorded_trace_replays_identically_across_policies() {
    let cfg = cfg();
    let scenario = Scenario::by_name("mixed-fleet").unwrap();
    let (_, exec) =
        run_scenario_recorded(&cfg, Policy::AgentServe(Default::default()), &scenario, 9);
    assert!(
        exec.events
            .iter()
            .any(|e| matches!(e.kind, ExecEventKind::Classified { .. })),
        "execution log must record classifications"
    );
    // What `scenario record` writes: scripts + realized arrivals.
    let (rec_out, trace) =
        record_scenario_trace(&cfg, Policy::AgentServe(Default::default()), &scenario, 9);
    assert_eq!(rec_out.report.completed_sessions, scenario.total_sessions);
    // Open-loop scenarios realize exactly their planned arrivals.
    let planned: Vec<u64> = scenario
        .instantiate(cfg.model.kind, 9)
        .trace
        .events
        .iter()
        .map(|e| e.arrival_us)
        .collect();
    assert_eq!(rec_out.arrivals_us, planned);
    // JSONL round-trip preserves the workload bit-for-bit.
    let back = Trace::from_jsonl(&trace.to_jsonl()).unwrap();
    assert_eq!(back, trace);
    // Two consecutive replays are identical, under every policy.
    for policy in Policy::paper_lineup() {
        let a = run_sim_trace(&cfg, policy, &back);
        let b = run_sim_trace(&cfg, policy, &back);
        assert_eq!(a.report.total_tokens, b.report.total_tokens, "{}", policy.name());
        assert_eq!(
            a.report.completed_sessions,
            b.report.completed_sessions,
            "{}",
            policy.name()
        );
        assert_eq!(key(&a.report), key(&b.report), "{}", policy.name());
        assert_eq!(a.report.completed_sessions, back.len());
        assert_eq!(a.report.total_tokens, back.total_decode_tokens());
    }
}

/// Golden-trace snapshot: replaying `rust/tests/data/golden_trace.jsonl`
/// through `Policy::AgentServe` must reproduce the pinned RunReport summary
/// in `rust/tests/data/golden_report.json` **exactly** (string equality of
/// the deterministic JSON form).
///
/// Regenerating after an *intentional* scheduling/cost-model change:
///
/// ```sh
/// AGENTSERVE_BLESS=1 cargo test --test scenarios golden_trace_snapshot
/// # or: rm rust/tests/data/golden_report.json && cargo test --test scenarios
/// ```
///
/// then commit the refreshed snapshot alongside the change. The *trace*
/// (`golden_trace.jsonl`) is hand-written input and is never regenerated.
#[test]
fn golden_trace_snapshot() {
    let data = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data");
    let trace = Trace::load_jsonl(data.join("golden_trace.jsonl")).unwrap();
    assert_eq!(trace.len(), 4, "golden trace is four hand-written sessions");
    assert_eq!(trace.total_decode_tokens(), 566, "hand-computed scripted total");

    let cfg = cfg();
    let out = run_sim_trace(&cfg, Policy::AgentServe(Default::default()), &trace);
    assert_eq!(out.report.completed_sessions, 4);
    assert_eq!(out.report.total_tokens, 566);

    let summary = out.report.to_value().to_string_pretty();
    let snap = data.join("golden_report.json");
    if std::env::var("AGENTSERVE_BLESS").is_ok() || !snap.exists() {
        // Bless-on-absence bootstraps the snapshot in the first environment
        // that can execute the suite (the authoring container had no Rust
        // toolchain). Before writing, require a second independent replay to
        // reproduce the summary byte-for-byte, so a blessed pin is at least
        // internally deterministic. COMMIT the written file — until it is
        // checked in, this gate only protects within a single checkout.
        let again = run_sim_trace(&cfg, Policy::AgentServe(Default::default()), &trace);
        assert_eq!(
            again.report.to_value().to_string_pretty(),
            summary,
            "replay is not deterministic; refusing to bless"
        );
        std::fs::write(&snap, &summary).unwrap();
        eprintln!(
            "golden_trace_snapshot: blessed {} — commit this file to arm the gate",
            snap.display()
        );
        return;
    }
    let pinned = std::fs::read_to_string(&snap).unwrap();
    assert_eq!(
        summary, pinned,
        "replay diverged from the pinned golden report; if this change is \
         intentional, regenerate per this test's doc comment and commit the \
         new snapshot"
    );
}
