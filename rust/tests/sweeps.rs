//! Sweep-engine regression suite (tier-1): sweep reports are byte-
//! deterministic in `(Config, SweepSpec, policies, base_seed)`, the FCFS
//! baseline's open-loop p99 TTFT is monotone in arrival rate (head-of-line
//! blocking sanity), the agent-count axis really scales the fleet, and the
//! CSV form stays in lock-step with the JSON form.

use agentserve::engine::{run_scenario_fast, Policy};
use agentserve::workload::{run_sweep, Scenario, SweepAxis, SweepSpec};

mod common;
use common::cfg;

/// Small open-loop ReAct fleet (kept tiny so the suite stays fast).
fn small_open_loop(sessions: usize) -> Scenario {
    common::open_loop("sweep-test-fleet", 1.0, sessions)
}

#[test]
fn sweep_report_is_byte_deterministic() {
    let cfg = cfg();
    let spec = SweepSpec {
        name: "det-sweep".into(),
        description: String::new(),
        base: small_open_loop(10),
        axis: SweepAxis::ArrivalRate(vec![0.5, 2.0]),
    };
    let policies = [Policy::AgentServe(Default::default()), Policy::LlamaCpp];
    let a = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    let b = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    assert_eq!(
        a.to_value().to_string(),
        b.to_value().to_string(),
        "same (Config, SweepSpec, seed) must serialize byte-identically"
    );
    assert_eq!(a.to_csv(), b.to_csv());
    // A different base seed must actually change the workload.
    let c = run_sweep(&cfg, &spec, &policies, 8).unwrap();
    assert_ne!(a.to_value().to_string(), c.to_value().to_string());
    // Shape checks: every point carries every policy, in run order.
    assert_eq!(a.points.len(), 2);
    for pt in &a.points {
        assert_eq!(pt.per_policy.len(), 2);
        assert_eq!(pt.per_policy[0].policy, "AgentServe");
        assert_eq!(pt.per_policy[1].policy, "llama.cpp");
        for pp in &pt.per_policy {
            assert_eq!(pp.completed, 10, "{}: every session completes", pp.policy);
        }
    }
    assert_eq!(a.knees.len(), 2);
    // CSV row count: header + points × policies.
    assert_eq!(a.to_csv().lines().count(), 1 + 2 * 2);
}

#[test]
fn fcfs_p99_ttft_monotone_in_arrival_rate() {
    // With one seed, the Poisson inter-arrival draws are identical across
    // rates, so raising the rate compresses the same arrival sequence onto
    // the same service demands — under the FCFS (llama.cpp-style unchunked
    // FIFO) baseline, queueing delay can only grow (Lindley recursion with
    // smaller inter-arrival gaps), so p99 TTFT must not decrease.
    let cfg = cfg();
    let spec = SweepSpec {
        name: "mono-sweep".into(),
        description: String::new(),
        base: small_open_loop(40),
        axis: SweepAxis::ArrivalRate(vec![0.25, 2.0, 16.0]),
    };
    spec.validate().unwrap();
    let mut last = 0.0f64;
    for i in 0..3 {
        let sc = spec.scenario_at(i);
        let out = run_scenario_fast(&cfg, Policy::LlamaCpp, &sc, 7);
        assert_eq!(out.report.completed_sessions, 40);
        let p99 = out.report.ttft.p99;
        assert!(
            p99 >= last * 0.95,
            "p99 TTFT fell from {last:.1} ms to {p99:.1} ms at rate {}",
            spec.axis.value_at(i)
        );
        last = p99;
    }
    // The extremes must differ by a wide margin: overload is real.
    let lo = run_scenario_fast(&cfg, Policy::LlamaCpp, &spec.scenario_at(0), 7);
    assert!(
        last > lo.report.ttft.p99 * 2.0,
        "64x the arrival rate must visibly degrade tail TTFT ({} vs {})",
        last,
        lo.report.ttft.p99
    );
}

#[test]
fn agent_count_axis_scales_the_fleet() {
    let cfg = cfg();
    let spec = SweepSpec {
        name: "count-sweep".into(),
        description: String::new(),
        base: small_open_loop(4),
        axis: SweepAxis::AgentCount(vec![3, 6]),
    };
    let report = run_sweep(&cfg, &spec, &[Policy::Vllm], 5).unwrap();
    let sizes: Vec<usize> = report.points.iter().map(|p| p.sessions).collect();
    assert_eq!(sizes, vec![3, 6]);
    for pt in &report.points {
        assert_eq!(pt.per_policy[0].completed, pt.sessions);
    }
    // Per-point seeds decorrelate the grid.
    assert_ne!(report.points[0].seed, report.points[1].seed);
}

#[test]
fn knee_reported_under_overload() {
    // Drive the FCFS baseline far past saturation: a burst of cold prefills
    // at 50/s must push p99 TTFT over the calibrated SLO somewhere in the
    // grid, so the knee is identified (AgentServe may or may not knee —
    // only the baseline's knee existence is asserted).
    let cfg = cfg();
    let spec = SweepSpec {
        name: "knee-sweep".into(),
        description: String::new(),
        base: small_open_loop(24),
        axis: SweepAxis::ArrivalRate(vec![0.5, 50.0]),
    };
    let report = run_sweep(&cfg, &spec, &[Policy::LlamaCpp], 7).unwrap();
    let (policy, knee) = &report.knees[0];
    assert_eq!(policy, "llama.cpp");
    assert!(
        knee.is_some(),
        "24 cold prefills at 50/s must violate the {} ms TTFT SLO",
        report.slo_ttft_ms
    );
}

#[test]
fn registry_sweeps_are_byte_identical_at_any_worker_count() {
    // The tentpole lock at the integration level: a real registry sweep
    // (mix-shift exercises the mix axis and multi-policy merge) run at
    // several worker-pool widths must serialize byte-identically to the
    // `threads == 1` legacy serial loop — the CI smoke (`ci/check.sh`)
    // re-checks this through the CLI with `--threads 1` vs `--threads 4`.
    use agentserve::workload::run_sweep_with_threads;
    let cfg = cfg();
    let spec = SweepSpec::by_name("mix-shift").unwrap();
    let policies = [Policy::AgentServe(Default::default()), Policy::Vllm];
    let serial = run_sweep_with_threads(&cfg, &spec, &policies, 7, 1).unwrap();
    for threads in [2, 4, 9] {
        let par = run_sweep_with_threads(&cfg, &spec, &policies, 7, threads).unwrap();
        assert_eq!(
            serial.to_value().to_string(),
            par.to_value().to_string(),
            "{threads} workers diverged from the serial sweep"
        );
        assert_eq!(serial.to_csv(), par.to_csv(), "{threads} workers diverged (CSV)");
    }
    // The env/default-resolving entry point agrees with the explicit one.
    let auto = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    assert_eq!(serial.to_value().to_string(), auto.to_value().to_string());
}
