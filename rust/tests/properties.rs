//! Property-based tests over randomized inputs (in-tree RNG; proptest is
//! unavailable offline). Each property runs hundreds of randomized cases
//! with seeds printed on failure for reproduction.

use agentserve::config::SchedulerConfig;
use agentserve::coordinator::TpotScheduler;
use agentserve::greenctx::GreenContextPool;
use agentserve::kvcache::{BlockAllocator, RadixPrefixCache};
use agentserve::metrics::percentile;
use agentserve::util::json::{parse, Value};
use agentserve::util::rng::Rng;

mod common;

// ---------------------------------------------------------------------------
// KV allocator: invariants hold under arbitrary operation sequences.
// ---------------------------------------------------------------------------

#[test]
fn prop_allocator_invariants_under_random_ops() {
    for seed in 0..50 {
        let mut rng = Rng::seed_from_u64(seed);
        let blocks = 16 + (rng.next_u64() % 64) as usize;
        let mut alloc = BlockAllocator::new(blocks, 16);
        let mut live: Vec<u32> = Vec::new();
        for _ in 0..400 {
            match rng.next_u64() % 3 {
                0 => {
                    let n = 1 + (rng.next_u64() % 4) as usize;
                    if let Ok(bs) = alloc.allocate(n) {
                        live.extend(bs);
                    }
                }
                1 if !live.is_empty() => {
                    let i = (rng.next_u64() % live.len() as u64) as usize;
                    let b = live.swap_remove(i);
                    alloc.release(b).unwrap();
                }
                2 if !live.is_empty() => {
                    let i = (rng.next_u64() % live.len() as u64) as usize;
                    let b = live[i];
                    alloc.retain(b).unwrap();
                    live.push(b);
                }
                _ => {}
            }
            alloc.check_invariants().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
        // Drain: everything must return to the free list.
        for b in live {
            alloc.release(b).unwrap();
        }
        assert_eq!(alloc.used_blocks(), 0, "seed {seed}: leak");
        alloc.check_invariants().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Radix cache: lookups agree with a naive longest-common-prefix model.
// ---------------------------------------------------------------------------

#[test]
fn prop_radix_matches_naive_prefix_model() {
    for seed in 0..30 {
        let mut rng = Rng::seed_from_u64(1000 + seed);
        let bs = 8usize;
        let mut alloc = BlockAllocator::new(4096, bs);
        let mut radix = RadixPrefixCache::new();
        // Naive model: the set of inserted token sequences.
        let mut inserted: Vec<Vec<u32>> = Vec::new();
        for _ in 0..20 {
            // Random sequence, sometimes sharing a prefix with a previous one.
            let toks: Vec<u32> = if !inserted.is_empty() && rng.f64() < 0.5 {
                let base = &inserted[(rng.next_u64() % inserted.len() as u64) as usize];
                let keep_blocks = (rng.next_u64() % (base.len() / bs + 1) as u64) as usize;
                let mut t = base[..keep_blocks * bs].to_vec();
                let extra = bs * (1 + (rng.next_u64() % 3) as usize);
                t.extend((0..extra).map(|_| rng.range_u32(0, 30)));
                t
            } else {
                let len = bs * (1 + (rng.next_u64() % 5) as usize);
                (0..len).map(|_| rng.range_u32(0, 30)).collect()
            };
            let blocks = alloc.allocate_for_tokens(toks.len()).unwrap();
            radix.insert(&toks, &blocks, &mut alloc);
            inserted.push(toks);

            // Query a random sequence; expected hit = longest block-aligned
            // common prefix with any inserted sequence.
            let q: Vec<u32> = {
                let base = &inserted[(rng.next_u64() % inserted.len() as u64) as usize];
                let mut t = base.clone();
                if rng.f64() < 0.5 && !t.is_empty() {
                    let cut = (rng.next_u64() % t.len() as u64) as usize;
                    t.truncate(cut.max(1));
                }
                if rng.f64() < 0.3 {
                    let l = t.len();
                    if l > 0 {
                        t[l - 1] = 99; // diverge at tail
                    }
                }
                t
            };
            let expected = inserted
                .iter()
                .map(|s| {
                    let mut m = 0;
                    while m + bs <= q.len().min(s.len()) && q[m..m + bs] == s[m..m + bs] {
                        m += bs;
                    }
                    m
                })
                .max()
                .unwrap_or(0);
            let (hit, leased) = radix.lookup(&q, &mut alloc);
            assert_eq!(hit, expected, "seed {seed}: query {q:?}");
            for b in leased {
                alloc.release(b).unwrap();
            }
        }
        alloc.check_invariants().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Scheduler: control variables always within configured bounds.
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_bounds_hold_for_any_signal() {
    for seed in 0..40 {
        let mut rng = Rng::seed_from_u64(2000 + seed);
        let cfg = SchedulerConfig {
            theta_low_ms: 5.0 + rng.f64() * 20.0,
            theta_high_ms: 30.0 + rng.f64() * 50.0,
            delta_r: 1 + (rng.next_u64() % 16) as u32,
            delta_b: 1 + (rng.next_u64() % 64) as u32,
            interval_ms: 50.0,
            b_min: 8,
            b_max: 512,
            b_init: 128,
            r_base: 4,
            r_init: 16,
        };
        let total_sms = 32 + (rng.next_u64() % 96) as u32;
        let mut s = TpotScheduler::new(cfg.clone(), total_sms);
        for t in 0..500u64 {
            // Arbitrary (possibly wild) TPOT signals.
            for _ in 0..(rng.next_u64() % 4) {
                s.record_decode_step(rng.f64() * 300_000.0);
            }
            s.tick(t * 50_000);
            assert!((cfg.b_min..=cfg.b_max).contains(&s.b_prefill()), "seed {seed}");
            assert!((cfg.r_base..=total_sms).contains(&s.r_min()), "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// Green contexts: selection is the true minimum feasible slot.
// ---------------------------------------------------------------------------

#[test]
fn prop_greenctx_selects_min_feasible_slot() {
    for seed in 0..30 {
        let mut rng = Rng::seed_from_u64(3000 + seed);
        let sms = 16 + (rng.next_u64() % 240) as u32;
        let slots = 2 + (rng.next_u64() % 18) as usize;
        if sms < slots as u32 {
            continue;
        }
        let pool = GreenContextPool::new(sms, slots, 50.0);
        for _ in 0..100 {
            let target = 1 + (rng.next_u64() % (sms as u64 * 2)) as u32;
            let part = pool.partition_for_decode_sms(target);
            // Brute-force the minimal feasible slot.
            let expected = pool
                .slot_sizes()
                .iter()
                .copied()
                .filter(|&s| s >= target)
                .min()
                .unwrap_or(*pool.slot_sizes().last().unwrap());
            assert_eq!(part.decode_sms, expected, "seed {seed} target {target}");
            assert_eq!(part.decode_sms + part.prefill_sms, sms);
        }
    }
}

// ---------------------------------------------------------------------------
// Percentiles: agree with a naive definition and are monotone in q.
// ---------------------------------------------------------------------------

#[test]
fn prop_percentile_monotone_and_bounded() {
    for seed in 0..50 {
        let mut rng = Rng::seed_from_u64(4000 + seed);
        let n = 1 + (rng.next_u64() % 200) as usize;
        let samples: Vec<f64> = (0..n).map(|_| rng.f64() * 1000.0).collect();
        let lo = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut prev = f64::NEG_INFINITY;
        for q in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
            let v = percentile(&samples, q);
            assert!(v >= prev - 1e-12, "seed {seed}: must be monotone in q");
            assert!(((lo - 1e-12)..=(hi + 1e-12)).contains(&v), "seed {seed}: bounded");
            prev = v;
        }
        assert_eq!(percentile(&samples, 0.0), lo);
        assert_eq!(percentile(&samples, 100.0), hi);
    }
}

// ---------------------------------------------------------------------------
// JSON: random value trees round-trip through emit + parse.
// ---------------------------------------------------------------------------

fn random_value(rng: &mut Rng, depth: usize) -> Value {
    match if depth == 0 { rng.next_u64() % 4 } else { rng.next_u64() % 6 } {
        0 => Value::Null,
        1 => Value::Bool(rng.f64() < 0.5),
        2 => Value::Num((rng.f64() * 2e6).round() - 1e6),
        3 => {
            let len = (rng.next_u64() % 12) as usize;
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.range_u32(0, 5);
                    match c {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => 'é',
                        4 => '😀',
                        _ => 'a',
                    }
                })
                .collect();
            Value::Str(s)
        }
        4 => {
            let len = (rng.next_u64() % 5) as usize;
            Value::Arr((0..len).map(|_| random_value(rng, depth - 1)).collect())
        }
        _ => {
            let len = (rng.next_u64() % 5) as usize;
            Value::Obj(
                (0..len)
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn prop_json_round_trips() {
    for seed in 0..200 {
        let mut rng = Rng::seed_from_u64(5000 + seed);
        let v = random_value(&mut rng, 3);
        let compact = v.to_string();
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&compact).unwrap(), v, "seed {seed} compact");
        assert_eq!(parse(&pretty).unwrap(), v, "seed {seed} pretty");
    }
}

// ---------------------------------------------------------------------------
// Scenario arrival processes: sampled statistics match their definitions.
// ---------------------------------------------------------------------------

mod arrivals {
    use agentserve::config::ModelKind;
    use agentserve::util::rng::Rng;
    use agentserve::workload::{ArrivalProcess, Population, Scenario, WorkloadKind};

    pub fn scenario_with(
        arrivals: ArrivalProcess,
        populations: Vec<Population>,
        n: usize,
    ) -> Scenario {
        Scenario {
            name: "prop".into(),
            description: String::new(),
            arrivals,
            populations,
            total_sessions: n,
            n_agents: 4,
            kv: None,
            workflow: None,
            chaos: None,
            autoscale: None,
            host: None,
            obs: None,
        }
    }

    pub fn interarrivals(sc: &Scenario, seed: u64, n: usize) -> Vec<u64> {
        let mut rng = Rng::seed_from_u64(seed);
        let times = sc.arrival_times(&mut rng, n);
        assert_eq!(times.len(), n);
        for w in times.windows(2) {
            assert!(w[0] <= w[1], "seed {seed}: arrivals must be non-decreasing");
        }
        // Include the first gap (process starts at virtual t=0).
        let mut gaps = Vec::with_capacity(n);
        let mut prev = 0u64;
        for &t in &times {
            gaps.push(t - prev);
            prev = t;
        }
        gaps
    }

    pub const MODEL: ModelKind = ModelKind::Qwen3B;
    pub use ArrivalProcess::{Bursty, Poisson};
    pub use WorkloadKind::{PlanAndExecute, ReAct};
    pub fn react_pop(weight: f64) -> Population {
        Population::new("react", ReAct, weight)
    }
    pub fn pe_pop(weight: f64) -> Population {
        Population::new("planner", PlanAndExecute, weight)
    }
}

#[test]
fn prop_poisson_interarrival_mean_matches_rate() {
    use arrivals::*;
    let n = 4000;
    for seed in 0..5u64 {
        for rate in [0.5f64, 2.0, 10.0] {
            let sc = scenario_with(Poisson { rate_per_s: rate }, vec![react_pop(1.0)], n);
            let gaps = interarrivals(&sc, 7000 + seed, n);
            let mean = gaps.iter().sum::<u64>() as f64 / n as f64;
            let expect = 1e6 / rate;
            let rel = (mean - expect).abs() / expect;
            assert!(
                rel < 0.10,
                "seed {seed} rate {rate}: inter-arrival mean {mean:.0} us vs 1/rate {expect:.0} us (rel {rel:.3})"
            );
        }
    }
}

#[test]
fn prop_bursty_respects_burst_and_idle_bounds() {
    use arrivals::*;
    for seed in 0..10u64 {
        // Randomized-but-valid burst shapes, from the in-tree RNG.
        let mut meta = agentserve::util::rng::Rng::seed_from_u64(8000 + seed);
        let burst_size = 2 + (meta.next_u64() % 5) as u32; // 2..=6
        let intra_gap_us = 5_000 + meta.next_u64() % 45_000;
        let idle_min_us = 200_000 + meta.next_u64() % 300_000;
        let idle_max_us = idle_min_us + 100_000 + meta.next_u64() % 900_000;
        let n = (burst_size as usize) * 40 + 3; // includes a partial tail burst
        let sc = scenario_with(
            Bursty { burst_size, intra_gap_us, idle_min_us, idle_max_us },
            vec![react_pop(1.0)],
            n,
        );
        sc.validate().unwrap();
        let gaps = interarrivals(&sc, 9000 + seed, n);
        // gaps[0] is the start-of-time gap (0); gaps[i] for i>=1 separates
        // arrival i-1 from i: an idle gap iff i-1 closed a burst.
        assert_eq!(gaps[0], 0, "seed {seed}: first arrival at t=0");
        for (i, &g) in gaps.iter().enumerate().skip(1) {
            if (i as u32) % burst_size == 0 {
                assert!(
                    (idle_min_us..=idle_max_us).contains(&g),
                    "seed {seed}: idle gap {g} outside [{idle_min_us}, {idle_max_us}] at {i}"
                );
            } else {
                assert_eq!(
                    g, intra_gap_us,
                    "seed {seed}: intra-burst gap at {i} must equal {intra_gap_us}"
                );
            }
        }
    }
}

#[test]
fn prop_mixed_fleet_fractions_converge_to_weights() {
    use arrivals::*;
    let n = 3000;
    for seed in 0..5u64 {
        for weights in [vec![0.7, 0.3], vec![0.5, 0.25, 0.25]] {
            let populations: Vec<_> = weights
                .iter()
                .enumerate()
                .map(|(i, &w)| if i % 2 == 0 { react_pop(w) } else { pe_pop(w) })
                .collect();
            let sc = scenario_with(Poisson { rate_per_s: 5.0 }, populations, n);
            let wl = sc.instantiate(MODEL, 10_000 + seed);
            assert_eq!(wl.population_of.len(), n);
            let total: f64 = weights.iter().sum();
            for (p, &w) in weights.iter().enumerate() {
                let count = wl.population_of.iter().filter(|&&x| x == p).count();
                let frac = count as f64 / n as f64;
                let expect = w / total;
                assert!(
                    (frac - expect).abs() < 0.05,
                    "seed {seed}: population {p} fraction {frac:.3} vs weight {expect:.3}"
                );
            }
            // Scripts carry their population's workload kind.
            for (e, &p) in wl.trace.events.iter().zip(&wl.population_of) {
                assert_eq!(e.script.kind, sc.populations[p].workload, "seed {seed}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Simulation: conservation laws hold for random workloads and policies.
// ---------------------------------------------------------------------------

#[test]
fn prop_sim_conserves_tokens_across_policies() {
    use agentserve::config::{Config, GpuKind, ModelKind};
    use agentserve::engine::{run_sim, Policy, SimParams};
    use agentserve::workload::{WorkloadGenerator, WorkloadKind};

    for seed in 0..8 {
        let mut rng = Rng::seed_from_u64(6000 + seed);
        let model = ModelKind::ALL[(rng.next_u64() % 3) as usize];
        let gpu = [GpuKind::A5000, GpuKind::Rtx5090][(rng.next_u64() % 2) as usize];
        let wk = [WorkloadKind::ReAct, WorkloadKind::PlanAndExecute][(rng.next_u64() % 2) as usize];
        let n = 3 + (rng.next_u64() % 4) as usize;
        let cfg = Config::preset(model, gpu);
        let params = SimParams {
            n_agents: n,
            sessions_per_agent: 1,
            workload: wk,
            seed: seed * 7 + 1,
            ..SimParams::default()
        };
        // Expected totals from the scripts themselves.
        let mut gen = WorkloadGenerator::new(wk, model, params.seed);
        let scripts = gen.sessions(n);
        let expected_decode: u64 = scripts.iter().map(|s| s.total_decode_tokens()).sum();
        for policy in Policy::paper_lineup() {
            let out = run_sim(&cfg, policy, &params);
            assert_eq!(
                out.report.total_tokens, expected_decode,
                "seed {seed} {model}/{gpu}/{wk}/{policy:?}"
            );
            assert_eq!(out.report.completed_sessions, n);
            // TTFT count = one per request = 1 cold + steps resumes.
            let expected_requests: u64 =
                scripts.iter().map(|s| 1 + s.steps.len() as u64).sum();
            assert_eq!(out.report.ttft.n, expected_requests);
        }
    }
}

// ---------------------------------------------------------------------------
// Autoscale control plane: band bounds, purity, and the inert-path lock.
// ---------------------------------------------------------------------------

#[test]
fn prop_autoscaled_fleet_never_leaves_its_band() {
    // Randomized valid controller configs over an overloaded open loop:
    // whatever the controller does, the realized fleet size stays inside
    // [min_replicas, max_replicas] and every session still completes.
    use agentserve::cluster::run_cluster_fast;
    use agentserve::config::{AutoscaleConfig, RouterPolicy};
    use agentserve::engine::Policy;
    use agentserve::workload::Scenario;

    let cfg = common::cfg();
    for seed in 0..6u64 {
        let mut rng = Rng::seed_from_u64(11_000 + seed);
        let min = 1 + (rng.next_u64() % 2) as usize;
        let max = min + 1 + (rng.next_u64() % 3) as usize;
        let up = 0.5 + rng.f64() * 3.5;
        let sc = Scenario {
            autoscale: Some(AutoscaleConfig {
                interval_us: 200_000 + rng.next_u64() % 600_000,
                min_replicas: min,
                max_replicas: max,
                up_thresh: up,
                down_thresh: up / 4.0,
                sustain_ticks: 1 + (rng.next_u64() % 3) as u32,
                cooldown_us: rng.next_u64() % 5_000_000,
                boot_us: 1 + rng.next_u64() % 3_000_000,
            }),
            ..common::open_loop("band-prop", 4.0, 60)
        };
        sc.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let router = RouterPolicy::ALL[(seed % 4) as usize];
        let out = run_cluster_fast(
            &cfg,
            Policy::AgentServe(Default::default()),
            &sc,
            min,
            router,
            70 + seed,
        )
        .unwrap();
        assert_eq!(
            out.report.completed_sessions, 60,
            "seed {seed}/{router}: scaling must never lose a session"
        );
        if let Some(a) = &out.report.autoscale {
            assert!(
                a.peak_replicas <= max,
                "seed {seed}/{router}: peak {} exceeded the ceiling {max}",
                a.peak_replicas
            );
            assert!(
                (min..=max).contains(&a.final_replicas),
                "seed {seed}/{router}: final size {} left the band [{min}, {max}]",
                a.final_replicas
            );
            assert!(
                a.time_at_size_us.len() <= max + 1,
                "seed {seed}/{router}: time was accounted at a size above the ceiling"
            );
            assert!(a.replica_us > 0, "seed {seed}/{router}: the GPU-time integral is live");
        }
    }
}

#[test]
fn prop_fleet_size_is_a_pure_function_of_seed_scenario_config() {
    // The controller holds no hidden state: reruns of one
    // (config, scenario, seed) tuple reproduce the whole report — including
    // the realized size trajectory — byte-for-byte, and a different seed
    // actually changes the run.
    use agentserve::cluster::run_cluster_fast;
    use agentserve::config::RouterPolicy;
    use agentserve::engine::Policy;
    use agentserve::workload::Scenario;

    let cfg = common::cfg();
    let sc = Scenario::by_name("diurnal-burst").unwrap();
    let run = |seed| {
        run_cluster_fast(
            &cfg,
            Policy::AgentServe(Default::default()),
            &sc,
            1,
            RouterPolicy::LeastOutstanding,
            seed,
        )
        .unwrap()
    };
    let a = run(7);
    let b = run(7);
    assert_eq!(
        a.report.to_value().to_string(),
        b.report.to_value().to_string(),
        "same (scenario, seed) must reproduce the autoscaled run byte-for-byte"
    );
    let sa = a.report.autoscale.as_ref().expect("diurnal bursts drive the controller");
    let sb = b.report.autoscale.as_ref().unwrap();
    assert_eq!(sa.time_at_size_us, sb.time_at_size_us, "identical size trajectory");
    assert_eq!(sa.replica_us, sb.replica_us);
    let c = run(8);
    assert_ne!(
        a.report.to_value().to_string(),
        c.report.to_value().to_string(),
        "a different seed must change the workload"
    );
}

#[test]
fn prop_never_triggering_thresholds_match_the_static_fleet_bytes() {
    // The inert-path lock: an absent config, the inert default
    // (interval 0), and an active-but-never-triggering band (unreachable
    // up_thresh, strict `< 0` down_thresh) must all produce byte-identical
    // static-fleet reports under every router — and the never-triggering
    // run must not emit an autoscale block.
    use agentserve::cluster::run_cluster_fast;
    use agentserve::config::{AutoscaleConfig, RouterPolicy};
    use agentserve::engine::Policy;
    use agentserve::workload::Scenario;

    let cfg = common::cfg();
    let plain = Scenario::by_name("mixed-fleet").unwrap();
    let lockstep = Scenario {
        autoscale: Some(AutoscaleConfig {
            up_thresh: 1e12,
            down_thresh: 0.0,
            ..AutoscaleConfig::banded(1, 4)
        }),
        ..plain.clone()
    };
    lockstep.validate().unwrap();
    let inert = Scenario { autoscale: Some(AutoscaleConfig::default()), ..plain.clone() };
    inert.validate().unwrap();
    for router in RouterPolicy::ALL {
        for replicas in [1usize, 2] {
            let run = |sc: &Scenario| {
                run_cluster_fast(
                    &cfg,
                    Policy::AgentServe(Default::default()),
                    sc,
                    replicas,
                    router,
                    7,
                )
                .unwrap()
            };
            let a = run(&plain);
            let b = run(&lockstep);
            let c = run(&inert);
            let tag = format!("{router}/{replicas} replicas");
            assert!(
                b.report.autoscale.is_none(),
                "{tag}: a controller that never acts must not report stats"
            );
            assert_eq!(
                a.report.to_value().to_string(),
                b.report.to_value().to_string(),
                "{tag}: never-triggering thresholds must not perturb a single byte"
            );
            assert_eq!(
                a.report.to_value().to_string(),
                c.report.to_value().to_string(),
                "{tag}: the inert default must take the exact legacy path"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Experiment grids: worker count is invisible in the artifact bytes.
// ---------------------------------------------------------------------------

#[test]
fn prop_experiment_grids_are_byte_identical_at_any_worker_count() {
    use agentserve::engine::Policy;
    use agentserve::workload::{
        run_experiment, CellOverride, ExpAxis, ExperimentAxis, ExperimentSpec,
    };

    let cfg = common::cfg();
    for seed in 0..8u64 {
        let mut rng = Rng::seed_from_u64(seed);
        // Random grid: a rate axis (1-2 values), coin-flip replicas axis.
        let rate_pool = [0.5, 1.0, 2.0];
        let n_rates = 1 + (rng.next_u64() % 2) as usize;
        let start = (rng.next_u64() % 2) as usize;
        let rates: Vec<f64> = rate_pool[start..start + n_rates].to_vec();
        let mut axes = vec![ExperimentAxis { axis: ExpAxis::Rate, values: rates.clone() }];
        let with_fleet = rng.next_u64() % 2 == 0;
        if with_fleet {
            axes.push(ExperimentAxis { axis: ExpAxis::Replicas, values: vec![1.0, 2.0] });
        }
        // Coin-flip override: pin a random cell's seed and (on fleet
        // grids) bump its replica count.
        let mut overrides = Vec::new();
        if rng.next_u64() % 2 == 0 {
            let rate = rates[(rng.next_u64() % rates.len() as u64) as usize];
            let mut when = vec![(ExpAxis::Rate, rate)];
            let mut set = Vec::new();
            if with_fleet {
                when.push((ExpAxis::Replicas, 1.0));
                set.push((ExpAxis::Replicas, 2.0));
            }
            overrides.push(CellOverride { when, set, seed: Some(rng.next_u64() >> 1) });
        }
        let policies = if rng.next_u64() % 2 == 0 {
            vec![Policy::paper_lineup()[0]]
        } else {
            Policy::paper_lineup()[..2].to_vec()
        };
        let spec = ExperimentSpec {
            name: format!("prop-{seed}"),
            description: String::new(),
            base: common::open_loop("prop-base", 1.0, 5),
            policies,
            router: None,
            seed: None,
            axes,
            overrides,
        };
        spec.validate().unwrap_or_else(|e| panic!("seed {seed}: generated spec invalid: {e}"));
        let serial = run_experiment(&cfg, &spec, 7, 1).unwrap();
        let serial_json = serial.to_value().to_string();
        let serial_csv = serial.to_csv();
        // Rerun stability at width 1, then byte-identity at random widths.
        let again = run_experiment(&cfg, &spec, 7, 1).unwrap();
        assert_eq!(
            serial_json,
            again.to_value().to_string(),
            "seed {seed}: serial rerun drifted"
        );
        for _ in 0..2 {
            let w = 2 + (rng.next_u64() % 7) as usize;
            let par = run_experiment(&cfg, &spec, 7, w).unwrap();
            assert_eq!(
                serial_json,
                par.to_value().to_string(),
                "seed {seed}: {w} workers diverged from serial"
            );
            assert_eq!(serial_csv, par.to_csv(), "seed {seed}: {w} workers diverged (CSV)");
        }
    }
}

// ---------------------------------------------------------------------------
// Host execution model: queue waits are pure functions of (seed, scenario,
// config), and contention conserves the scripted token budget.
// ---------------------------------------------------------------------------

#[test]
fn prop_host_queue_is_deterministic_and_conserves_tokens() {
    // Randomized valid host configs (worker count, dispatch overhead,
    // latency shape) over both tool paths — scripted-session mixes and
    // workflow carriers: reruns are byte-identical, a new seed is a new
    // run, and queueing delays work without dropping or duplicating it.
    use agentserve::config::{HostConfig, HostLatency};
    use agentserve::engine::{run_scenario_fast, Policy};
    use agentserve::workload::Scenario;

    let cfg = common::cfg();
    for seed in 0..8u64 {
        let mut rng = Rng::seed_from_u64(13_000 + seed);
        let latency = match rng.next_u64() % 3 {
            0 => HostLatency::Fixed,
            1 => {
                let lo = 0.25 + rng.f64();
                HostLatency::Uniform { lo, hi: lo + 0.1 + rng.f64() }
            }
            _ => HostLatency::LogNormal { mu: 0.0, sigma: 0.2 + rng.f64() },
        };
        let host = HostConfig {
            cpu_workers: 1 + (rng.next_u64() % 4) as usize,
            dispatch_overhead_us: rng.next_u64() % 3_000,
            latency,
        };
        host.validate().unwrap_or_else(|e| panic!("seed {seed}: generated config invalid: {e}"));
        let base = if rng.next_u64() % 2 == 0 {
            common::open_loop("host-prop", 2.0, 24)
        } else {
            common::wf_scenario("supervisor-worker", 6, 1.0)
        };
        let sc = Scenario { host: Some(host), ..base };
        sc.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let run_seed = 70 + seed;
        let policy = Policy::paper_lineup()[(seed % 4) as usize];
        let a = run_scenario_fast(&cfg, policy, &sc, run_seed);
        let b = run_scenario_fast(&cfg, policy, &sc, run_seed);
        assert_eq!(
            a.report.to_value().to_string(),
            b.report.to_value().to_string(),
            "seed {seed}: same (scenario, seed) must rerun byte-identically"
        );
        let (ha, hb) = (a.host.as_ref().unwrap(), b.host.as_ref().unwrap());
        assert_eq!(
            ha.to_value().to_string(),
            hb.to_value().to_string(),
            "seed {seed}: host waits must replay exactly"
        );
        // Conservation under contention: the scripted decode budget is
        // emitted exactly once and no session is lost to the queue.
        assert_eq!(
            a.report.total_tokens,
            common::scripted_tokens(&cfg, &sc, run_seed),
            "seed {seed}: queueing must conserve the scripted token budget"
        );
        assert_eq!(a.report.completed_sessions, a.report.sessions, "seed {seed}");
        let c = run_scenario_fast(&cfg, policy, &sc, run_seed + 1);
        assert_ne!(
            a.report.to_value().to_string(),
            c.report.to_value().to_string(),
            "seed {seed}: a new seed must change the run"
        );
    }
}

// ---------------------------------------------------------------------------
// Observability: telemetry is write-only and grid-exact for any valid
// probe interval.
// ---------------------------------------------------------------------------

#[test]
fn prop_probe_grid_is_exact_and_write_only_for_any_interval() {
    // Randomized valid probe intervals (floor up to 2 s), with and without
    // tracing, over both workload shapes: the report stays byte-identical
    // to the unobserved run, sample i sits exactly at (i+1)×interval (no
    // skips, no duplicates), and the artifacts rerun byte-identically.
    use agentserve::config::{ObsConfig, ProbeConfig};
    use agentserve::engine::{run_scenario_fast, Policy};
    use agentserve::workload::Scenario;

    let cfg = common::cfg();
    for seed in 0..8u64 {
        let mut rng = Rng::seed_from_u64(17_000 + seed);
        let interval = ProbeConfig::MIN_INTERVAL_US * (1 + rng.next_u64() % 2_000);
        let obs = ObsConfig {
            trace: rng.next_u64() % 2 == 0,
            probe: ProbeConfig::every_us(interval),
        };
        obs.validate()
            .unwrap_or_else(|e| panic!("seed {seed}: generated config invalid: {e}"));
        let plain = if rng.next_u64() % 2 == 0 {
            common::open_loop("obs-prop", 2.0, 24)
        } else {
            Scenario::by_name("mixed-fleet").unwrap()
        };
        let sc = Scenario { obs: Some(obs), ..plain.clone() };
        sc.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let run_seed = 70 + seed;
        let policy = Policy::paper_lineup()[(seed % 4) as usize];
        let observed = run_scenario_fast(&cfg, policy, &sc, run_seed);
        let unobserved = run_scenario_fast(&cfg, policy, &plain, run_seed);
        assert_eq!(
            observed.report.to_value().to_string(),
            unobserved.report.to_value().to_string(),
            "seed {seed}: telemetry must be write-only at any interval"
        );
        let log = observed.obs.as_ref().expect("active probe => log");
        let probes = log.probes.as_ref().expect("active probe => probe log");
        assert_eq!(probes.interval_us, interval);
        for (i, s) in probes.samples.iter().enumerate() {
            assert_eq!(
                s.t_us,
                (i as u64 + 1) * interval,
                "seed {seed}: sample {i} off the {interval} us grid"
            );
            assert_eq!((s.replica, s.serving_replicas), (0, 1), "seed {seed}");
        }
        let again = run_scenario_fast(&cfg, policy, &sc, run_seed);
        let again_log = again.obs.as_ref().unwrap();
        assert_eq!(
            probes.to_value().to_string(),
            again_log.probes.as_ref().unwrap().to_value().to_string(),
            "seed {seed}: probe log must rerun byte-identically"
        );
        if obs.trace {
            assert_eq!(
                log.to_chrome_trace(observed.phases.as_ref()).to_string(),
                again_log.to_chrome_trace(again.phases.as_ref()).to_string(),
                "seed {seed}: trace must rerun byte-identically"
            );
        }
    }
}
