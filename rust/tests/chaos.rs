//! Chaos-layer integration tests.
//!
//! The two contracts this suite locks:
//! - **Zero-fault purity**: an absent *or inert* chaos config keeps the
//!   fleet on the exact legacy code path — reports are byte-identical
//!   under every router policy.
//! - **Deterministic chaos**: with faults active (scripted, seeded, or
//!   tool-level), reruns of the same `(config, seed)` are byte-identical,
//!   sessions are never lost (crashed work is re-routed and recomputed),
//!   and the scripted decode-token budget is conserved up to the tokens
//!   the crash forced the fleet to redecode.

use agentserve::cluster::run_cluster_fast;
use agentserve::config::{ChaosConfig, FaultEvent, FaultKind, RouterPolicy};
use agentserve::engine::{run_scenario, Policy};
use agentserve::workflow::{ToolFaultPolicy, WorkflowLoad, WorkflowSpec};
use agentserve::workload::{run_sweep, Scenario, SweepAxis, SweepSpec};

mod common;
use common::{cfg, scripted_tokens};

#[test]
fn inert_chaos_config_keeps_the_legacy_bytes_under_every_router() {
    // `chaos: None` and an attached-but-inert config (no events, mtbf 0)
    // must both take the legacy path: same report bytes, no chaos block.
    let cfg = cfg();
    let plain = Scenario::by_name("mixed-fleet").unwrap();
    let inert = Scenario { chaos: Some(ChaosConfig::default()), ..plain.clone() };
    for policy in [Policy::AgentServe(Default::default()), Policy::Vllm] {
        for router in RouterPolicy::ALL {
            let a = run_cluster_fast(&cfg, policy, &plain, 2, router, 7).unwrap();
            let b = run_cluster_fast(&cfg, policy, &inert, 2, router, 7).unwrap();
            let tag = format!("{}/{}", policy.name(), router);
            assert!(a.report.chaos.is_none(), "{tag}: no chaos block without faults");
            assert_eq!(
                a.report.to_value().to_string(),
                b.report.to_value().to_string(),
                "{tag}: an inert chaos config must not perturb a single byte"
            );
        }
    }
}

#[test]
fn failure_storm_reruns_are_byte_identical() {
    // The registry chaos scenario (seeded crashes + flaky tools) is a pure
    // function of (config, seed): rerun → same bytes; new seed → new run.
    let cfg = cfg();
    let sc = Scenario::by_name("failure-storm").unwrap();
    sc.validate().unwrap();
    let policy = Policy::AgentServe(Default::default());
    let a = run_cluster_fast(&cfg, policy, &sc, 3, RouterPolicy::CacheAware, 7).unwrap();
    let b = run_cluster_fast(&cfg, policy, &sc, 3, RouterPolicy::CacheAware, 7).unwrap();
    assert_eq!(
        a.report.to_value().to_string(),
        b.report.to_value().to_string(),
        "same (scenario, seed) must serialize byte-identically"
    );
    let c = run_cluster_fast(&cfg, policy, &sc, 3, RouterPolicy::CacheAware, 8).unwrap();
    assert_ne!(a.report.to_value().to_string(), c.report.to_value().to_string());
    // Chaos counters are reported, and no session is ever lost: crashed
    // work is re-routed and finishes elsewhere.
    assert!(a.report.chaos.is_some(), "active chaos always reports its block");
    assert_eq!(a.report.completed_sessions, a.report.sessions);
    let wf = a.report.workflow.as_ref().expect("failure-storm carries a workflow");
    assert_eq!(wf.tasks, 12);
}

#[test]
fn scripted_crash_conserves_tokens_and_reroutes_sessions() {
    // One crash at t=200ms on a 2-replica fleet: every session still
    // completes, and the fleet emits exactly the scripted decode budget
    // plus whatever the crash forced it to redecode.
    let cfg = cfg();
    let base = Scenario::by_name("mixed-fleet").unwrap();
    let sc = Scenario {
        chaos: Some(ChaosConfig {
            events: vec![FaultEvent { at_us: 200_000, replica: 0, kind: FaultKind::Crash }],
            mtbf_us: 0,
            restart_us: 2_000_000,
        }),
        ..base
    };
    sc.validate().unwrap();
    let expected = scripted_tokens(&cfg, &sc, 7);
    for router in [RouterPolicy::RoundRobin, RouterPolicy::CacheAware] {
        let out = run_cluster_fast(&cfg, Policy::Vllm, &sc, 2, router, 7).unwrap();
        let chaos = out.report.chaos.expect("scripted crash reports chaos stats");
        assert_eq!(chaos.crashes, 1, "{router}");
        assert!(chaos.downtime_ms > 0.0, "{router}");
        assert_eq!(
            out.report.completed_sessions, out.report.sessions,
            "{router}: crashed sessions must be re-routed, not dropped"
        );
        assert_eq!(
            out.report.total_tokens,
            expected + chaos.redecoded_tokens,
            "{router}: decode tokens conserved up to crash-forced recompute"
        );
    }
}

#[test]
fn graceful_drain_loses_no_work() {
    // Drain replica 0 early, restore it later: nothing in flight is lost,
    // so nothing is redecoded and the scripted budget is emitted exactly.
    let cfg = cfg();
    let sc = Scenario {
        chaos: Some(ChaosConfig {
            events: vec![
                FaultEvent { at_us: 200_000, replica: 0, kind: FaultKind::Drain },
                FaultEvent { at_us: 5_000_000, replica: 0, kind: FaultKind::Restore },
            ],
            mtbf_us: 0,
            restart_us: 2_000_000,
        }),
        ..Scenario::by_name("mixed-fleet").unwrap()
    };
    sc.validate().unwrap();
    let expected = scripted_tokens(&cfg, &sc, 7);
    let out = run_cluster_fast(&cfg, Policy::Vllm, &sc, 2, RouterPolicy::RoundRobin, 7).unwrap();
    let chaos = out.report.chaos.expect("drain reports chaos stats");
    assert_eq!(chaos.drains, 1);
    assert_eq!(chaos.crashes, 0);
    assert_eq!(chaos.redecoded_tokens, 0, "a drain finishes its queue; no recompute");
    assert_eq!(out.report.completed_sessions, out.report.sessions);
    assert_eq!(out.report.total_tokens, expected);
}

#[test]
fn retry_exhaustion_fails_the_task_instead_of_hanging() {
    // A near-certain tool failure with 2 attempts: the run must terminate,
    // every session still completes (the delay propagates through the DAG),
    // and the exhausted tasks are reported failed — excluded from task-SLO
    // attainment rather than wedging the join barrier.
    let cfg = cfg();
    let mut load = WorkflowLoad::new(WorkflowSpec::by_name("supervisor-worker").unwrap());
    load.tool_fault = Some(ToolFaultPolicy {
        fail_prob: 0.999,
        timeout_us: 1_000_000,
        max_attempts: 2,
        backoff_base_us: 100_000,
    });
    let sc = Scenario { name: "exhaust".into(), ..load.carrier(4, 1.0) };
    sc.validate().unwrap();
    let out = run_scenario(&cfg, Policy::AgentServe(Default::default()), &sc, 7);
    let wf = out.workflow.expect("workflow metrics present");
    assert_eq!(out.report.completed_sessions, out.report.sessions, "no hang");
    assert!(wf.failed_tasks > 0, "exhaustion must surface as failed tasks");
    assert!(wf.tool_retries > 0);
    assert!(wf.failed_tasks <= wf.tasks);

    // The same load on a fleet reports the counters through the chaos
    // block even with zero replica faults (tool faults alone gate it).
    let fleet = run_cluster_fast(&cfg, Policy::Vllm, &sc, 2, RouterPolicy::RoundRobin, 7).unwrap();
    let chaos = fleet.report.chaos.expect("tool faults alone report a chaos block");
    assert_eq!(chaos.crashes, 0);
    assert!(chaos.failed_tasks > 0);
    assert!(chaos.tool_retries > 0);
    assert_eq!(fleet.report.completed_sessions, fleet.report.sessions);
}

#[test]
fn chaos_sweep_degrades_slo_attainment() {
    // The resilience axis end-to-end: byte-deterministic reruns, and a
    // violent crash rate (mtbf 2 s ~ the restart latency, so replicas are
    // down half the time) cannot beat the fault-free baseline.
    let cfg = cfg();
    let spec = SweepSpec {
        name: "chaos-test".into(),
        description: String::new(),
        base: Scenario::by_name("mixed-fleet").unwrap(),
        axis: SweepAxis::Chaos {
            rates_per_min: vec![0.0, 30.0],
            replicas: 2,
            router: RouterPolicy::RoundRobin,
        },
    };
    spec.validate().unwrap();
    let policies = [Policy::Vllm];
    let report = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    let again = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    assert_eq!(report.to_value().to_string(), again.to_value().to_string());
    assert_eq!(report.axis, "chaos");
    assert_eq!(report.points.len(), 2);
    let baseline = &report.points[0].per_policy[0];
    let stormy = &report.points[1].per_policy[0];
    assert!(
        stormy.slo_rate <= baseline.slo_rate,
        "crashing half the fleet's uptime away must not improve SLO attainment \
         ({} vs baseline {})",
        stormy.slo_rate,
        baseline.slo_rate
    );
    assert!(
        stormy.ttft_p99 >= baseline.ttft_p99,
        "re-routed cold recomputes can only lengthen the TTFT tail"
    );
}
