//! Observability-layer integration tests (tier-1).
//!
//! The contracts this suite locks:
//! - **Inert purity**: an absent *or inert* obs config keeps every run on
//!   the exact legacy code path — reports are byte-identical under the
//!   whole paper policy lineup and every router, and no log is attached.
//! - **Write-only telemetry**: an *active* observer never perturbs the
//!   simulation — traced and untraced runs of the same `(scenario, seed)`
//!   produce byte-identical reports; the observer only adds artifacts.
//! - **Span algebra**: per session, phase children tile the root span
//!   exactly (no gaps, no overlaps), and the latency decomposition
//!   `queue + kv_stall + host_wait + compute == latency` holds, as does
//!   per-slot GPU-time conservation `busy + idle == wall`.
//! - **Determinism**: traces and probe logs are pure functions of
//!   `(seed, scenario, config)` — reruns are byte-identical, a new seed
//!   is a new trace — and the 1-replica fleet emits the batch run's exact
//!   artifacts.
//! - **Crash continuity**: spans from crashed replica incarnations
//!   survive the fleet merge (the truncated root and its re-routed rerun
//!   share one global session id), and chaos faults appear as instants.

use std::collections::BTreeMap;

use agentserve::cluster::run_cluster_fast;
use agentserve::config::{
    ChaosConfig, FaultEvent, FaultKind, ObsConfig, ProbeConfig, RouterPolicy,
};
use agentserve::engine::{run_scenario, run_scenario_fast, Policy};
use agentserve::obs::{InstantKind, Span, SpanKind};
use agentserve::workload::Scenario;

mod common;
use common::cfg;

/// Scenario with an obs block layered on.
fn with_obs(base: &Scenario, obs: ObsConfig) -> Scenario {
    Scenario { obs: Some(obs), ..base.clone() }
}

/// Tracing and a 20 ms probe grid, together.
fn full_obs() -> ObsConfig {
    ObsConfig { trace: true, probe: ProbeConfig::every_us(20_000) }
}

#[test]
fn inert_obs_config_keeps_the_legacy_bytes_under_every_policy_and_router() {
    // `obs: None` and an attached-but-inert config (trace off, probe off)
    // must both take the legacy path: same report bytes, no log attached.
    let cfg = cfg();
    let plain = Scenario::by_name("mixed-fleet").unwrap();
    let inert = with_obs(&plain, ObsConfig::default());
    inert.validate().unwrap();
    for policy in Policy::paper_lineup() {
        for router in RouterPolicy::ALL {
            let a = run_cluster_fast(&cfg, policy, &plain, 2, router, 7).unwrap();
            let b = run_cluster_fast(&cfg, policy, &inert, 2, router, 7).unwrap();
            let tag = format!("{}/{}", policy.name(), router);
            assert!(a.obs.is_none() && b.obs.is_none(), "{tag}: inert => no log");
            assert!(a.report.phases.is_none(), "{tag}: inert => no attribution");
            assert_eq!(
                a.report.to_value().to_string(),
                b.report.to_value().to_string(),
                "{tag}: an inert obs config must not perturb a single byte"
            );
        }
    }
    // Same contract on the single-GPU path.
    for name in ["paper-fig5", "burst-storm"] {
        let plain = Scenario::by_name(name).unwrap();
        let inert = with_obs(&plain, ObsConfig::default());
        for policy in Policy::paper_lineup() {
            let a = run_scenario_fast(&cfg, policy, &plain, 7);
            let b = run_scenario_fast(&cfg, policy, &inert, 7);
            assert!(a.obs.is_none() && b.obs.is_none());
            assert!(a.phases.is_none() && b.phases.is_none());
            assert_eq!(
                a.report.to_value().to_string(),
                b.report.to_value().to_string(),
                "{name}/{}: inert obs must keep the legacy bytes",
                policy.name()
            );
        }
    }
}

#[test]
fn an_active_observer_never_perturbs_the_simulation() {
    // Telemetry is write-only: the traced run's *report* is byte-identical
    // to the untraced run's. (tool-storm exercises host waits, paper-fig5
    // the adaptive knobs, memory-pressure KV stalls and preemption.)
    let cfg = cfg();
    for name in ["paper-fig5", "tool-storm", "memory-pressure"] {
        let plain = Scenario::by_name(name).unwrap();
        let traced = with_obs(&plain, full_obs());
        for policy in Policy::paper_lineup() {
            let a = run_scenario_fast(&cfg, policy, &plain, 7);
            let b = run_scenario_fast(&cfg, policy, &traced, 7);
            assert_eq!(
                a.report.to_value().to_string(),
                b.report.to_value().to_string(),
                "{name}/{}: an active observer must not move a single byte",
                policy.name()
            );
            assert!(b.obs.is_some(), "{name}: active obs attaches the log");
            assert!(b.phases.is_some(), "{name}: tracing attaches attribution");
        }
    }
    // Fleet form: the merged per-replica reports must agree byte-for-byte
    // (the fleet report itself legitimately gains a `phases` block).
    let plain = Scenario::by_name("mixed-fleet").unwrap();
    let traced = with_obs(&plain, full_obs());
    let a = run_cluster_fast(&cfg, Policy::Vllm, &plain, 2, RouterPolicy::CacheAware, 7).unwrap();
    let b = run_cluster_fast(&cfg, Policy::Vllm, &traced, 2, RouterPolicy::CacheAware, 7).unwrap();
    assert_eq!(a.per_replica.len(), b.per_replica.len());
    for (ra, rb) in a.per_replica.iter().zip(&b.per_replica) {
        assert_eq!(
            ra.report.to_value().to_string(),
            rb.report.to_value().to_string(),
            "traced fleet replicas must run the identical simulation"
        );
    }
    assert_eq!(a.report.completed_sessions, b.report.completed_sessions);
    assert_eq!(a.report.total_tokens, b.report.total_tokens);
    assert!(b.report.phases.is_some() && b.obs.is_some());
}

#[test]
fn span_children_tile_their_root_and_the_decomposition_conserves_latency() {
    let cfg = cfg();
    let sc = with_obs(&Scenario::by_name("paper-fig5").unwrap(), ObsConfig::traced());
    let out = run_scenario(&cfg, Policy::AgentServe(Default::default()), &sc, 7);
    let log = out.obs.expect("traced run keeps the span log");
    let pr = out.phases.expect("traced run attributes GPU time");

    let mut roots: BTreeMap<u64, &Span> = BTreeMap::new();
    let mut children: BTreeMap<u64, Vec<&Span>> = BTreeMap::new();
    for s in &log.spans {
        assert!(s.end_us >= s.start_us, "spans close forward in time");
        assert!(s.end_us > s.start_us || s.kind == SpanKind::Session,
            "zero-length phase spans are accounted, never emitted");
        assert_eq!(s.replica, 0, "single-GPU spans carry replica 0");
        if s.kind == SpanKind::Session {
            assert!(roots.insert(s.session, s).is_none(), "one root per session");
        } else {
            children.entry(s.session).or_default().push(s);
        }
    }
    assert_eq!(roots.len() as u64, pr.sessions, "every begun session has a root");
    assert!(!roots.is_empty());

    let mut latency_sum = 0u64;
    for (sess, root) in &roots {
        let mut kids = children.remove(sess).unwrap_or_default();
        kids.sort_by_key(|s| s.start_us);
        // Phase children tile the root exactly: each child starts where
        // the previous ended (zero-length closed phases are dropped, so
        // abutment is exact), and the last closes with the root.
        let mut cursor = root.start_us;
        for k in &kids {
            assert_eq!(k.start_us, cursor, "session {sess}: gap/overlap in span tree");
            cursor = k.end_us;
        }
        assert_eq!(cursor, root.end_us, "session {sess}: children must tile to the root");
        latency_sum += root.dur_us();
    }
    assert!(children.is_empty(), "no orphan child spans");

    // Latency decomposition checksum, and per-slot GPU-time conservation.
    assert_eq!(latency_sum, pr.latency_us, "root durations are the decomposition total");
    assert_eq!(
        pr.queue_us + pr.kv_stall_us + pr.host_wait_us + pr.compute_us,
        pr.latency_us,
        "queue + kv-stall + host-wait + compute must tile session latency"
    );
    assert_eq!(pr.replicas, 1);
    for (i, slot) in pr.slots.iter().enumerate() {
        assert_eq!(slot.total_us(), pr.wall_us, "slot {i}: busy + idle == wall");
    }
    assert!(pr.slots.iter().map(|s| s.busy_us()).sum::<u64>() > 0, "the run did work");
    assert!(pr.prefill_share() > 0.0 && pr.prefill_share() <= 1.0);

    // The adaptive policy ticks its controller; every tick is an instant
    // inside the run horizon.
    assert!(!log.instants.is_empty(), "AgentServe control ticks become instants");
    for i in &log.instants {
        assert!(matches!(i.kind, InstantKind::Control { .. }), "no chaos here");
        assert!(i.t_us <= pr.wall_us);
    }
}

#[test]
fn telemetry_artifacts_rerun_byte_identically() {
    // Trace + probe outputs are pure functions of (seed, scenario,
    // config); a new seed is a new trace.
    let cfg = cfg();
    let sc = with_obs(&Scenario::by_name("paper-fig5").unwrap(), full_obs());
    let policy = Policy::AgentServe(Default::default());
    let a = run_scenario(&cfg, policy, &sc, 7);
    let b = run_scenario(&cfg, policy, &sc, 7);
    let (la, lb) = (a.obs.as_ref().unwrap(), b.obs.as_ref().unwrap());
    let trace_a = la.to_chrome_trace(a.phases.as_ref()).to_string();
    assert_eq!(
        trace_a,
        lb.to_chrome_trace(b.phases.as_ref()).to_string(),
        "same (scenario, seed) must serialize byte-identically"
    );
    let (pa, pb) = (la.probes.as_ref().unwrap(), lb.probes.as_ref().unwrap());
    assert!(!pa.samples.is_empty(), "a 20 ms grid must sample this run");
    assert_eq!(pa.to_value().to_string(), pb.to_value().to_string());
    assert_eq!(pa.to_csv(), pb.to_csv());
    let c = run_scenario(&cfg, policy, &sc, 8);
    assert_ne!(
        trace_a,
        c.obs.as_ref().unwrap().to_chrome_trace(c.phases.as_ref()).to_string(),
        "a new seed must be a new trace"
    );
    // Fleet artifacts obey the same law.
    let fsc = with_obs(&Scenario::by_name("mixed-fleet").unwrap(), full_obs());
    let fa = run_cluster_fast(&cfg, Policy::Vllm, &fsc, 3, RouterPolicy::CacheAware, 7).unwrap();
    let fb = run_cluster_fast(&cfg, Policy::Vllm, &fsc, 3, RouterPolicy::CacheAware, 7).unwrap();
    let (fla, flb) = (fa.obs.as_ref().unwrap(), fb.obs.as_ref().unwrap());
    assert_eq!(
        fla.to_chrome_trace(fa.report.phases.as_ref()).to_string(),
        flb.to_chrome_trace(fb.report.phases.as_ref()).to_string(),
        "fleet traces must rerun byte-identically"
    );
    assert_eq!(
        fla.probes.as_ref().unwrap().to_csv(),
        flb.probes.as_ref().unwrap().to_csv()
    );
}

#[test]
fn one_replica_fleet_emits_the_batch_runs_exact_artifacts() {
    // The fleet's pre-event probe/tick discipline reduces exactly to the
    // batch sampler on a 1-replica, fault-free fleet: same spans, same
    // instants, same probe rows, same attribution — byte for byte.
    let cfg = cfg();
    let sc = with_obs(&Scenario::by_name("paper-fig5").unwrap(), full_obs());
    let policy = Policy::AgentServe(Default::default());
    let single = run_scenario_fast(&cfg, policy, &sc, 7);
    let fleet = run_cluster_fast(&cfg, policy, &sc, 1, RouterPolicy::RoundRobin, 7).unwrap();
    let (ls, lf) = (single.obs.as_ref().unwrap(), fleet.obs.as_ref().unwrap());
    assert_eq!(
        ls.to_chrome_trace(single.phases.as_ref()).to_string(),
        lf.to_chrome_trace(fleet.report.phases.as_ref()).to_string(),
        "1-replica fleet trace must equal the batch trace"
    );
    assert_eq!(
        ls.probes.as_ref().unwrap().to_csv(),
        lf.probes.as_ref().unwrap().to_csv(),
        "1-replica fleet probe rows must equal the batch rows"
    );
}

#[test]
fn probe_samples_land_on_the_grid_in_order() {
    // Probe-only runs: samples sit exactly on the fixed grid, one full
    // interval in, strictly increasing; no spans, no attribution.
    let cfg = cfg();
    let interval = 20_000u64;
    let sc = with_obs(&Scenario::by_name("paper-fig5").unwrap(), ObsConfig::probed(interval));
    let out = run_scenario(&cfg, Policy::Vllm, &sc, 7);
    assert!(out.phases.is_none(), "attribution is a tracing artifact");
    let log = out.obs.unwrap();
    assert!(log.spans.is_empty(), "probe-only runs record no spans");
    let probes = log.probes.expect("active probe => log");
    assert_eq!(probes.interval_us, interval);
    assert!(probes.samples.len() > 2, "the run spans several grid points");
    for (i, s) in probes.samples.iter().enumerate() {
        assert_eq!(s.t_us, (i as u64 + 1) * interval, "samples sit on the grid");
        assert_eq!((s.replica, s.serving_replicas), (0, 1));
    }
}

#[test]
fn fleet_probe_grid_samples_every_serving_replica() {
    let cfg = cfg();
    let interval = 50_000u64;
    let sc = with_obs(&Scenario::by_name("mixed-fleet").unwrap(), ObsConfig::probed(interval));
    let out = run_cluster_fast(&cfg, Policy::Vllm, &sc, 3, RouterPolicy::RoundRobin, 7).unwrap();
    let probes = out.obs.unwrap().probes.expect("fleet-global probe grid");
    assert!(!probes.samples.is_empty());
    let mut by_t: BTreeMap<u64, Vec<_>> = BTreeMap::new();
    for s in &probes.samples {
        assert_eq!(s.t_us % interval, 0, "fleet samples sit on the shared grid");
        by_t.entry(s.t_us).or_default().push(s);
    }
    for (t, rows) in &by_t {
        // Healthy static fleet: one row per serving replica per grid
        // point, in replica order, each stamped with the serving count.
        assert_eq!(rows.len(), 3, "t={t}: one row per serving replica");
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.replica as usize, r, "t={t}: rows in replica order");
            assert_eq!(row.serving_replicas, 3, "t={t}: serving count stamped");
        }
    }
}

#[test]
fn crash_incarnation_spans_survive_the_fleet_merge() {
    // Scripted crash at t=200 ms on replica 0 of a 2-replica fleet: the
    // dead incarnation's spans are retagged and kept, the crash itself is
    // an instant at the fault time, and any session whose decoded work
    // was lost shows both its truncated root and its re-routed rerun
    // under one global session id.
    let cfg = cfg();
    let sc = Scenario {
        chaos: Some(ChaosConfig {
            events: vec![FaultEvent { at_us: 200_000, replica: 0, kind: FaultKind::Crash }],
            mtbf_us: 0,
            restart_us: 2_000_000,
        }),
        obs: Some(ObsConfig::traced()),
        ..Scenario::by_name("mixed-fleet").unwrap()
    };
    sc.validate().unwrap();
    let out = run_cluster_fast(&cfg, Policy::Vllm, &sc, 2, RouterPolicy::RoundRobin, 7).unwrap();
    let chaos = out.report.chaos.expect("scripted crash reports chaos stats");
    assert_eq!(chaos.crashes, 1);
    let log = out.obs.expect("traced fleet keeps the merged log");
    let crash_instants: Vec<_> = log
        .instants
        .iter()
        .filter(|i| matches!(&i.kind, InstantKind::Chaos { what } if what == "crash"))
        .collect();
    assert_eq!(crash_instants.len(), 1, "one scripted crash, one instant");
    assert_eq!(
        (crash_instants[0].t_us, crash_instants[0].replica),
        (200_000, 0),
        "the crash instant carries the fault time and replica"
    );
    let mut roots: BTreeMap<u64, Vec<&Span>> = BTreeMap::new();
    for s in &log.spans {
        assert!(s.replica < 2, "merged spans carry fleet replica ids");
        if s.kind == SpanKind::Session {
            roots.entry(s.session).or_default().push(s);
        }
    }
    assert_eq!(
        roots.len(),
        out.report.sessions,
        "every global session keeps at least one root through the merge"
    );
    if chaos.redecoded_tokens > 0 {
        // Lost decode work implies a session begun on the dead replica
        // was re-run: its truncated root ends at the crash, its rerun
        // completes later, same tid.
        let reruns: Vec<_> = roots.values().filter(|v| v.len() > 1).collect();
        assert!(
            !reruns.is_empty(),
            "redecoded tokens without a rerun root: crashed spans were dropped"
        );
        for incarnations in &reruns {
            // The dead incarnation seals at its last processed event, so
            // the truncated root closes at-or-before the fault instant;
            // the re-routed rerun can only finish after it.
            let earliest = incarnations.iter().map(|s| s.end_us).min().unwrap();
            let latest = incarnations.iter().map(|s| s.end_us).max().unwrap();
            assert!(earliest <= 200_000, "the truncated root closes by the crash");
            assert!(latest > 200_000, "the rerun root completes after the crash");
        }
    }

    // Chaos traces obey the same determinism law as everything else: the
    // registry failure-storm (seeded crashes + flaky tools) reruns its
    // merged trace byte-identically.
    let storm = with_obs(&Scenario::by_name("failure-storm").unwrap(), ObsConfig::traced());
    let policy = Policy::AgentServe(Default::default());
    let a = run_cluster_fast(&cfg, policy, &storm, 3, RouterPolicy::CacheAware, 7).unwrap();
    let b = run_cluster_fast(&cfg, policy, &storm, 3, RouterPolicy::CacheAware, 7).unwrap();
    assert_eq!(
        a.obs.as_ref().unwrap().to_chrome_trace(a.report.phases.as_ref()).to_string(),
        b.obs.as_ref().unwrap().to_chrome_trace(b.report.phases.as_ref()).to_string(),
        "failure-storm traces must rerun byte-identically"
    );
    assert_eq!(a.report.completed_sessions, a.report.sessions, "no session lost");
}

#[test]
fn fleet_phase_report_merges_replica_walls_and_sessions() {
    let cfg = cfg();
    let sc = with_obs(&Scenario::by_name("mixed-fleet").unwrap(), ObsConfig::traced());
    let out = run_cluster_fast(
        &cfg,
        Policy::AgentServe(Default::default()),
        &sc,
        2,
        RouterPolicy::CacheAware,
        7,
    )
    .unwrap();
    let pr = out.report.phases.expect("traced fleet reports attribution");
    assert_eq!(pr.replicas, 2);
    // The merge sums per-replica walls and slots, so the merged slot
    // totals cover two slots per summed wall.
    let total: u64 = pr.slots.iter().map(|s| s.total_us()).sum();
    assert_eq!(total, 2 * pr.wall_us, "Σ slot totals == 2 slots × merged wall");
    assert_eq!(
        pr.queue_us + pr.kv_stall_us + pr.host_wait_us + pr.compute_us,
        pr.latency_us,
        "the decomposition survives the fleet merge"
    );
    assert_eq!(pr.sessions as usize, out.report.sessions, "fault-free: begun == routed");
    assert!(pr.prefill_share() > 0.0 && pr.prefill_share() <= 1.0);
    let idle = pr.decode_idle_share();
    assert!((0.0..=1.0).contains(&idle), "idle share is a fraction (got {idle})");
}
