//! Shared helpers for the integration suites.
//!
//! Every suite pulls its `Config` preset and scenario builders from here
//! (`mod common;`) so that a new `Scenario` field — like the `autoscale`
//! control-plane block — is added in exactly one place instead of in a
//! dozen hand-rolled struct literals scattered across the suites.
#![allow(dead_code)]

use agentserve::config::{Config, GpuKind, ModelKind};
use agentserve::workflow::{compile, WorkflowLoad, WorkflowSpec};
use agentserve::workload::{ArrivalProcess, Population, Scenario, WorkloadKind};

/// The calibrated paper preset every suite runs on (Qwen-3B on an A5000).
pub fn cfg() -> Config {
    Config::preset(ModelKind::Qwen3B, GpuKind::A5000)
}

/// Open-loop Poisson ReAct fleet with every optional layer (bounded KV,
/// workflow DAG, chaos, autoscale) switched off — the baseline shape the
/// suites then specialize with struct-update syntax.
pub fn open_loop(name: &str, rate_per_s: f64, sessions: usize) -> Scenario {
    Scenario {
        name: name.into(),
        description: String::new(),
        arrivals: ArrivalProcess::Poisson { rate_per_s },
        populations: vec![Population::new("react", WorkloadKind::ReAct, 1.0)],
        total_sessions: sessions,
        n_agents: sessions,
        kv: None,
        workflow: None,
        chaos: None,
        autoscale: None,
        host: None,
        obs: None,
    }
}

/// Open-loop carrier releasing `tasks` instances of a registry workflow.
pub fn wf_scenario(spec_name: &str, tasks: usize, rate: f64) -> Scenario {
    Scenario {
        name: format!("wf-{spec_name}"),
        ..WorkflowLoad::new(WorkflowSpec::by_name(spec_name).expect("registry workflow"))
            .carrier(tasks, rate)
    }
}

/// Scripted decode tokens of a scenario instantiation (policy-independent;
/// workflow-aware — DAG scenarios compile to scripts first).
pub fn scripted_tokens(cfg: &Config, sc: &Scenario, seed: u64) -> u64 {
    if sc.workflow.is_some() {
        let cw = compile(sc, cfg.model.kind, seed);
        cw.scripts.iter().map(|s| s.total_decode_tokens()).sum()
    } else {
        sc.instantiate(cfg.model.kind, seed).trace.total_decode_tokens()
    }
}
