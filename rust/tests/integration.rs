//! Cross-module integration tests: end-to-end simulated serving, paired
//! policy comparisons, paper-shape assertions, and CLI plumbing.

use agentserve::config::{Config, GpuKind, ModelKind};
use agentserve::engine::{run_sim, AgentServeOpts, Policy, SimParams};
use agentserve::workload::WorkloadKind;

fn cfg(model: ModelKind, gpu: GpuKind) -> Config {
    Config::preset(model, gpu)
}

fn params(n: usize, sessions: usize) -> SimParams {
    SimParams {
        n_agents: n,
        sessions_per_agent: sessions,
        workload: WorkloadKind::ReAct,
        ..SimParams::default()
    }
}

#[test]
fn full_grid_completes_every_cell() {
    // Every (model, gpu, policy) cell must finish all sessions and conserve
    // the script-determined token counts.
    for model in ModelKind::ALL {
        for gpu in GpuKind::ALL {
            let cfg = cfg(model, gpu);
            let p = params(3, 1);
            let mut tokens = None;
            for policy in Policy::paper_lineup() {
                let out = run_sim(&cfg, policy, &p);
                assert_eq!(out.report.completed_sessions, 3, "{model}/{gpu}/{policy:?}");
                match tokens {
                    None => tokens = Some(out.report.total_tokens),
                    Some(t) => assert_eq!(
                        t, out.report.total_tokens,
                        "token conservation across policies ({model}/{gpu})"
                    ),
                }
            }
        }
    }
}

#[test]
fn paper_shape_agentserve_wins_slo() {
    // Fig. 6's core claim: AgentServe attains the most sessions at high
    // concurrency on the A5000.
    let cfg = cfg(ModelKind::Qwen3B, GpuKind::A5000);
    let p = params(6, 2);
    let ours = run_sim(&cfg, Policy::AgentServe(AgentServeOpts::default()), &p);
    for baseline in [Policy::Sglang(Default::default()), Policy::Vllm, Policy::LlamaCpp] {
        let b = run_sim(&cfg, baseline, &p);
        assert!(
            ours.slo.rate() > b.slo.rate(),
            "AgentServe SLO {:.2} must beat {} {:.2}",
            ours.slo.rate(),
            baseline.name(),
            b.slo.rate()
        );
    }
}

#[test]
fn paper_shape_tpot_tail_beats_mixed_engines() {
    // Fig. 5: request-level TPOT p95 improves on the single-engine mixed
    // baselines (vLLM chunked, llama.cpp unchunked).
    let cfg = cfg(ModelKind::Qwen3B, GpuKind::A5000);
    let p = params(5, 2);
    let ours = run_sim(&cfg, Policy::AgentServe(AgentServeOpts::default()), &p);
    for baseline in [Policy::Vllm, Policy::LlamaCpp] {
        let b = run_sim(&cfg, baseline, &p);
        assert!(
            ours.report.tpot.p95 * 1.5 < b.report.tpot.p95,
            "AgentServe TPOT p95 {:.1} must be >=1.5x better than {} {:.1}",
            ours.report.tpot.p95,
            baseline.name(),
            b.report.tpot.p95
        );
    }
}

#[test]
fn paper_shape_throughput_leads_at_high_concurrency() {
    let cfg = cfg(ModelKind::Qwen3B, GpuKind::A5000);
    let p = params(6, 3);
    let ours = run_sim(&cfg, Policy::AgentServe(AgentServeOpts::default()), &p);
    for baseline in [Policy::Sglang(Default::default()), Policy::Vllm, Policy::LlamaCpp] {
        let b = run_sim(&cfg, baseline, &p);
        assert!(
            ours.report.throughput_tok_s > b.report.throughput_tok_s,
            "AgentServe {:.1} tok/s must beat {} {:.1}",
            ours.report.throughput_tok_s,
            baseline.name(),
            b.report.throughput_tok_s
        );
    }
}

#[test]
fn ablations_degrade_the_full_system() {
    // Fig. 7: removing either mechanism hurts somewhere.
    let cfg = cfg(ModelKind::Qwen7B, GpuKind::A5000);
    let p = params(4, 2);
    let full = run_sim(&cfg, Policy::AgentServe(AgentServeOpts::default()), &p);
    let no_alg = run_sim(
        &cfg,
        Policy::AgentServe(AgentServeOpts { adaptive: false, green_contexts: true }),
        &p,
    );
    let no_green = run_sim(
        &cfg,
        Policy::AgentServe(AgentServeOpts { adaptive: true, green_contexts: false }),
        &p,
    );
    assert!(
        no_alg.report.ttft.p95 > full.report.ttft.p95,
        "No-Alg must inflate TTFT p95 ({} vs {})",
        no_alg.report.ttft.p95,
        full.report.ttft.p95
    );
    assert!(
        no_green.report.tpot.p95 > 1.2 * full.report.tpot.p95,
        "No-Green must inflate TPOT p95 ({} vs {})",
        no_green.report.tpot.p95,
        full.report.tpot.p95
    );
    assert!(full.slo.rate() >= no_alg.slo.rate());
    assert!(full.slo.rate() >= no_green.slo.rate());
}

#[test]
fn faster_gpu_improves_both_workloads() {
    for wk in WorkloadKind::ALL {
        let p = SimParams { workload: wk, ..params(4, 1) };
        let a = run_sim(
            &cfg(ModelKind::Qwen7B, GpuKind::A5000),
            Policy::AgentServe(AgentServeOpts::default()),
            &p,
        );
        let b = run_sim(
            &cfg(ModelKind::Qwen7B, GpuKind::Rtx5090),
            Policy::AgentServe(AgentServeOpts::default()),
            &p,
        );
        assert!(b.report.tpot.p50 < a.report.tpot.p50, "{wk}: 5090 must decode faster");
        assert!(b.report.wall_ms < a.report.wall_ms, "{wk}: 5090 must finish sooner");
    }
}

#[test]
fn plan_and_execute_reroutes_more_resumes() {
    // P&E resumes (125-421 tokens) blow the budget far more often than
    // ReAct's (30-127). Under a *static* budget (No-Alg: B = b_init = 128)
    // the classifier must reroute most P&E resumes and almost no ReAct
    // ones. (With adaptation, B legitimately grows to absorb P&E resumes
    // whenever decode is idle — so the static variant isolates the
    // classification rule.)
    let cfg = cfg(ModelKind::Qwen7B, GpuKind::A5000);
    let static_opts = AgentServeOpts { adaptive: false, green_contexts: true };
    let react = run_sim(
        &cfg,
        Policy::AgentServe(static_opts),
        &SimParams { workload: WorkloadKind::ReAct, ..params(4, 2) },
    );
    let pe = run_sim(
        &cfg,
        Policy::AgentServe(static_opts),
        &SimParams { workload: WorkloadKind::PlanAndExecute, ..params(4, 2) },
    );
    let react_frac =
        react.resume_rerouted as f64 / (react.resume_merged + react.resume_rerouted).max(1) as f64;
    let pe_frac =
        pe.resume_rerouted as f64 / (pe.resume_merged + pe.resume_rerouted).max(1) as f64;
    assert!(
        pe_frac > react_frac,
        "P&E reroute fraction {pe_frac:.2} must exceed ReAct's {react_frac:.2}"
    );
}

#[test]
fn rebind_overhead_stays_negligible() {
    // §III-C: rebinding must stay far below 0.1% of serving time.
    let cfg = cfg(ModelKind::Qwen3B, GpuKind::A5000);
    let out = run_sim(&cfg, Policy::AgentServe(AgentServeOpts::default()), &params(5, 2));
    let total_us = out.report.wall_ms * 1000.0;
    assert!(
        out.rebinds.total_us < 0.001 * total_us,
        "rebind time {} us exceeds 0.1% of {} us",
        out.rebinds.total_us,
        total_us
    );
}

#[test]
fn kv_capacity_pressure_defers_but_completes() {
    // Shrink the KV pool until admissions must wait; everything still
    // completes (back-pressure + preemption, not deadlock), and the paged
    // allocator structurally cannot exceed the configured capacity.
    let mut cfg = cfg(ModelKind::Qwen3B, GpuKind::A5000);
    cfg.kv.num_blocks = 700; // ~11k tokens: < 3 concurrent full sessions
    let out = run_sim(&cfg, Policy::AgentServe(AgentServeOpts::default()), &params(4, 2));
    assert_eq!(out.report.completed_sessions, 8);
    assert!(
        out.kv_peak_tokens <= 700 * 16,
        "peak {} must respect capacity",
        out.kv_peak_tokens
    );
    let kv = out.kv.expect("bounded pool runs the paged path");
    assert!(kv.peak_blocks <= 700);
    assert!(kv.stalls.n > 0, "4 concurrent sessions must stall on a ~2.4-session pool");
}

#[test]
fn cli_bench_and_analyze_smoke() {
    use agentserve::util::cli::Args;
    let run = |s: &str| {
        agentserve::server::run(Args::parse(s.split_whitespace().map(String::from)).unwrap())
    };
    run("bench --model 3b --gpu 5090 --agents 3 --sessions 1 --policy vllm").unwrap();
    run("analyze --model 3b --gpu a5000 --delta 6 --eps 0.02").unwrap();
}

#[test]
fn config_file_overrides_apply_in_sim() {
    let dir = std::env::temp_dir().join("agentserve_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.json");
    std::fs::write(
        &path,
        r#"{"model": "7b", "gpu": "5090", "engine": {"chunk_size": 64}}"#,
    )
    .unwrap();
    let cfg = Config::from_path(&path).unwrap();
    assert_eq!(cfg.engine.chunk_size, 64);
    assert_eq!(cfg.gpu.sm_count, 128);
    // Smaller chunks mean more vLLM iterations; the run still completes.
    let out = run_sim(&cfg, Policy::Vllm, &params(3, 1));
    assert_eq!(out.report.completed_sessions, 3);
}

#[test]
fn vllm_chunking_bounds_prefill_monopoly() {
    // Smaller chunks => more iterations sharing the device with decode =>
    // better TTFT tail for queued requests, worse aggregate throughput
    // (repeated weight reads). Both directions must show.
    let cfg = cfg(ModelKind::Qwen7B, GpuKind::A5000);
    let mut small = cfg.clone();
    small.engine.chunk_size = 64;
    let mut large = cfg.clone();
    large.engine.chunk_size = 1024;
    let p = params(5, 2);
    let s = run_sim(&small, Policy::Vllm, &p);
    let l = run_sim(&large, Policy::Vllm, &p);
    assert!(
        s.report.throughput_tok_s < l.report.throughput_tok_s,
        "small chunks must cost throughput ({} vs {})",
        s.report.throughput_tok_s,
        l.report.throughput_tok_s
    );
    assert!(
        s.report.tpot.p95 < l.report.tpot.p95,
        "small chunks must shorten decode stalls ({} vs {})",
        s.report.tpot.p95,
        l.report.tpot.p95
    );
}

#[test]
fn sglang_split_trades_ttft_for_tpot() {
    // The static-partition frontier: more decode share => smoother TPOT,
    // worse TTFT/throughput. This is the motivation for Algorithm 1.
    let cfg = cfg(ModelKind::Qwen7B, GpuKind::A5000);
    let p = params(5, 2);
    use agentserve::engine::SglangOpts;
    let lo = run_sim(&cfg, Policy::Sglang(SglangOpts { decode_share: 0.3 }), &p);
    let hi = run_sim(&cfg, Policy::Sglang(SglangOpts { decode_share: 0.7 }), &p);
    assert!(hi.report.tpot.p95 < lo.report.tpot.p95);
    assert!(hi.report.ttft.p95 > lo.report.ttft.p95);
    assert!(hi.report.throughput_tok_s < lo.report.throughput_tok_s);
}

#[test]
fn llamacpp_queues_whole_prompts() {
    // One prompt per iteration: with many simultaneous arrivals, later cold
    // prefills wait for earlier ones in full => TTFT p95 grows superlinearly
    // with concurrency compared to the TTFT p50.
    let cfg = cfg(ModelKind::Qwen7B, GpuKind::A5000);
    let lo = run_sim(&cfg, Policy::LlamaCpp, &params(3, 1));
    let hi = run_sim(&cfg, Policy::LlamaCpp, &params(6, 1));
    assert!(
        hi.report.ttft.p99 > 1.5 * lo.report.ttft.p99,
        "queueing must compound at N=6: {} vs {}",
        hi.report.ttft.p99,
        lo.report.ttft.p99
    );
}

#[test]
fn workloads_differ_as_characterized() {
    // P&E sessions carry more prefill work per decode token than... rather:
    // P&E resumes are much longer; ReAct cycles are more frequent. Check the
    // measured work mix (eta_cold lower for P&E since resumes are bigger).
    let cfg = cfg(ModelKind::Qwen7B, GpuKind::A5000);
    let react = run_sim(
        &cfg,
        Policy::AgentServe(AgentServeOpts::default()),
        &SimParams { workload: WorkloadKind::ReAct, ..params(4, 2) },
    );
    let pe = run_sim(
        &cfg,
        Policy::AgentServe(AgentServeOpts::default()),
        &SimParams { workload: WorkloadKind::PlanAndExecute, ..params(4, 2) },
    );
    assert!(
        pe.eta_cold < react.eta_cold,
        "P&E's long resumes must lower the cold fraction ({} vs {})",
        pe.eta_cold,
        react.eta_cold
    );
}

#[test]
fn green_granularity_tightens_rho_bound() {
    // Theorem 1: finer slots (smaller delta) retain more prefill service.
    use agentserve::coordinator::CompetitiveAnalyzer;
    use agentserve::gpusim::CostModel;
    use agentserve::greenctx::GreenContextPool;
    let cfg = cfg(ModelKind::Qwen7B, GpuKind::A5000);
    let cost = CostModel::new(&cfg.model, &cfg.gpu);
    let mut prev = 0.0;
    for slots in [4usize, 10, 20] {
        let pool = GreenContextPool::new(cfg.gpu.sm_count, slots, 50.0);
        let analyzer =
            CompetitiveAnalyzer::new(cost.clone(), pool.slot_sizes().to_vec(), cfg.gpu.sm_count);
        let rho = analyzer
            .bound(&cfg.slo, pool.granularity(), 0.01, 0.7)
            .expect("feasible")
            .rho_bound;
        assert!(rho >= prev, "finer slots must not lower the bound");
        prev = rho;
    }
    assert!(prev > 0.9, "10-20 slot bound should retain >90% prefill service");
}
