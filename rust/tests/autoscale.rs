//! Autoscale control-plane acceptance suite (tier-1).
//!
//! Locks the tentpole contracts of the deterministic fleet autoscaler on
//! its demo workload, the `diurnal-burst` registry scenario (bursts of 10
//! arrivals, then 20-30 s of quiet, carrying an active `[1, 4]` band):
//!
//! - **Frontier**: the autoscaled fleet beats the band-floor static fleet
//!   on tail TTFT while spending less GPU-time than the band-ceiling
//!   static fleet — the cost-vs-SLO frontier the control plane exists for.
//! - **Drains lose nothing**: scale-downs happen in the quiet valleys and
//!   the scripted decode-token budget is still emitted exactly once.
//! - **The `autoscale` sweep axis** maps the same frontier as data: the
//!   `up_thresh = 0` point is the provisioned-for-peak static baseline,
//!   autoscaled points undercut its `replica_us` cost column, and the
//!   whole report reruns byte-identically.

use agentserve::cluster::run_cluster_fast;
use agentserve::config::RouterPolicy;
use agentserve::engine::Policy;
use agentserve::workload::{run_sweep, Scenario, SweepAxis, SweepSpec};

mod common;
use common::{cfg, scripted_tokens};

#[test]
fn diurnal_burst_frontier_beats_both_static_extremes() {
    let cfg = cfg();
    let sc = Scenario::by_name("diurnal-burst").unwrap();
    let run = |scenario: &Scenario, replicas| {
        run_cluster_fast(
            &cfg,
            Policy::AgentServe(Default::default()),
            scenario,
            replicas,
            RouterPolicy::LeastOutstanding,
            7,
        )
        .unwrap()
    };
    let auto = run(&sc, 1);
    let static_sc = Scenario { autoscale: None, ..sc.clone() };
    let floor = run(&static_sc, 1);
    let ceiling = run(&static_sc, 4);
    for out in [&auto, &floor, &ceiling] {
        assert_eq!(out.report.completed_sessions, sc.total_sessions);
    }
    let stats = auto.report.autoscale.as_ref().expect("bursts of 10 drive the controller");
    assert!(stats.scale_ups > 0, "the controller must boot capacity into the bursts");
    assert!(stats.peak_replicas > 1 && stats.peak_replicas <= 4);
    // SLO side of the frontier: scaling into the bursts relieves the
    // queue the floor fleet cannot clear.
    assert!(
        auto.report.ttft.p99 < floor.report.ttft.p99,
        "autoscaled p99 TTFT ({:.1} ms) must beat the 1-replica static fleet ({:.1} ms)",
        auto.report.ttft.p99,
        floor.report.ttft.p99
    );
    // Cost side: the quiet valleys mean far less GPU-time than keeping the
    // band ceiling provisioned for the whole run.
    let ceiling_cost = 4 * (ceiling.report.wall_ms * 1000.0) as u64;
    assert!(
        stats.replica_us < ceiling_cost,
        "autoscaled GPU-time ({} replica-us) must undercut a pinned 4-replica fleet ({})",
        stats.replica_us,
        ceiling_cost
    );
}

#[test]
fn scale_downs_drain_without_losing_work() {
    // The 20-30 s valleys pull the fleet back to the floor (cooldown is
    // 5 s), so the run sees real drains — and the ledger still closes
    // exactly: a drained replica finishes everything placed on it first.
    let cfg = cfg();
    let sc = Scenario::by_name("diurnal-burst").unwrap();
    let expected = scripted_tokens(&cfg, &sc, 7);
    let out = run_cluster_fast(
        &cfg,
        Policy::AgentServe(Default::default()),
        &sc,
        1,
        RouterPolicy::CacheAware,
        7,
    )
    .unwrap();
    let stats = out.report.autoscale.as_ref().expect("the controller acted");
    assert!(stats.scale_ups > 0);
    assert!(stats.scale_downs > 0, "20-30 s valleys must drain the burst capacity back out");
    assert_eq!(out.report.completed_sessions, sc.total_sessions, "no session lost to a drain");
    assert_eq!(
        out.report.total_tokens, expected,
        "every scripted decode token exactly once — drains recompute nothing"
    );
    let sum: u64 = out.per_replica.iter().map(|o| o.report.total_tokens).sum();
    assert_eq!(sum, expected, "drained replicas keep their finished work in the ledger");
}

#[test]
fn autoscale_sweep_maps_the_cost_vs_slo_frontier() {
    let cfg = cfg();
    let spec = SweepSpec {
        name: "frontier-test".into(),
        description: String::new(),
        base: Scenario::by_name("diurnal-burst").unwrap(),
        axis: SweepAxis::Autoscale {
            up_threshes: vec![0.0, 2.0],
            min_replicas: 1,
            max_replicas: 4,
            router: RouterPolicy::LeastOutstanding,
        },
    };
    spec.validate().unwrap();
    let policies = [Policy::AgentServe(Default::default())];
    let report = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    let again = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    assert_eq!(
        report.to_value().to_string(),
        again.to_value().to_string(),
        "the frontier sweep must rerun byte-identically"
    );
    assert_eq!(report.axis, "autoscale");
    assert_eq!(report.points.len(), 2);
    let static_pt = &report.points[0].per_policy[0];
    let auto_pt = &report.points[1].per_policy[0];
    assert_eq!(
        static_pt.replicas, 4,
        "up_thresh 0 means policy off: the provisioned-for-peak static baseline"
    );
    assert_eq!(static_pt.completed, 40);
    assert_eq!(auto_pt.completed, 40);
    assert!(static_pt.replica_us > 0);
    assert!(
        auto_pt.replica_us < static_pt.replica_us,
        "the autoscaled point ({} replica-us) must undercut the static ceiling ({})",
        auto_pt.replica_us,
        static_pt.replica_us
    );
    // The cost column rides both serialized forms.
    assert!(report.to_csv().lines().next().unwrap().ends_with("replicas,load_cov,replica_us"));
    assert!(report.to_value().to_string().contains("\"replica_us\""));
}
