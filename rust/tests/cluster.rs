//! Fleet-layer integration tests.
//!
//! The anchor is the **pure-refactor lock**: a 1-replica cluster over an
//! open-loop scenario must reproduce `run_scenario` byte-for-byte under
//! every router policy — the `SimDriver` stepping refactor of
//! `engine/sim.rs` changes *how* the event loop is driven, never *what* it
//! computes. (Closed-loop and workflow scenarios re-route fleet-created
//! arrivals at their own timestamps; those are locked by conservation
//! instead — see `docs/ARCHITECTURE.md` § Fleet layer, determinism notes.)
//!
//! On top of that: token/session conservation across replicas for every
//! router, fleet-wide workflow join barriers across replicas,
//! session-affinity pinning, p99-TTFT monotonicity in replica count, the
//! cache-aware router beating round-robin on radix hits, and the
//! `gpus-for-slo` inverse knee.

use agentserve::cluster::{run_cluster, run_cluster_fast, FleetOutcome};
use agentserve::config::{KvConfig, RouterPolicy};
use agentserve::engine::{run_scenario, Policy};
use agentserve::workload::{Scenario, SweepAxis, SweepSpec};

mod common;
use common::{cfg, scripted_tokens};

/// A small open-loop workflow carrier (supervisor/worker joins).
fn workflow_scenario(tasks: usize) -> Scenario {
    Scenario { name: "sw-fleet".into(), ..common::wf_scenario("supervisor-worker", tasks, 0.5) }
}

#[test]
fn one_replica_cluster_reproduces_run_scenario_bytes() {
    // Open-loop scenarios (explicit arrival plans): the fleet loop's
    // injection order and sequence bands provably reproduce the batch
    // event order, so everything — report JSON, SLO, realized arrivals —
    // is byte-identical under every router (with one replica, all routers
    // return replica 0; the equivalence exercises the whole driver path).
    let cfg = cfg();
    for name in ["mixed-fleet", "burst-storm", "open-loop-sweep"] {
        let sc = Scenario::by_name(name).unwrap();
        for policy in Policy::paper_lineup() {
            let batch = run_scenario(&cfg, policy, &sc, 7);
            for router in RouterPolicy::ALL {
                let fleet = run_cluster(&cfg, policy, &sc, 1, router, 7).unwrap();
                let tag = format!("{name}/{}/{}", policy.name(), router);
                assert_eq!(fleet.per_replica.len(), 1, "{tag}");
                let rep = &fleet.per_replica[0];
                assert_eq!(
                    rep.report.to_value().to_string(),
                    batch.report.to_value().to_string(),
                    "{tag}: replica report must be byte-identical"
                );
                assert_eq!(rep.slo.attained, batch.slo.attained, "{tag}");
                assert_eq!(rep.arrivals_us, batch.arrivals_us, "{tag}");
                assert_eq!(rep.control_trace, batch.control_trace, "{tag}");
                assert_eq!(rep.eta_cold, batch.eta_cold, "{tag}");
                // Fleet-level aggregation agrees with the single replica.
                assert_eq!(fleet.report.total_tokens, batch.report.total_tokens, "{tag}");
                assert_eq!(fleet.report.slo.attained, batch.slo.attained, "{tag}");
                assert!(fleet.placements.iter().all(|&r| r == 0), "{tag}");
            }
        }
    }
}

#[test]
fn one_replica_paged_path_is_also_byte_identical() {
    // The same lock on the paged KV path (bounded pool + radix sharing):
    // admission, eviction, and the radix counters all ride the driver.
    let mut cfg = cfg();
    cfg.kv = KvConfig { num_blocks: 4096, block_size: 16, prefix_sharing: true };
    let sc = Scenario::by_name("mixed-fleet").unwrap();
    for policy in [Policy::AgentServe(Default::default()), Policy::Vllm] {
        let batch = run_scenario(&cfg, policy, &sc, 11);
        for router in RouterPolicy::ALL {
            let fleet = run_cluster(&cfg, policy, &sc, 1, router, 11).unwrap();
            let rep = &fleet.per_replica[0];
            let tag = format!("{}/{}", policy.name(), router);
            assert_eq!(
                rep.report.to_value().to_string(),
                batch.report.to_value().to_string(),
                "{tag}"
            );
            let (a, b) = (rep.kv.as_ref().unwrap(), batch.kv.as_ref().unwrap());
            assert_eq!(a.to_value().to_string(), b.to_value().to_string(), "{tag}");
        }
    }
}

#[test]
fn every_router_conserves_sessions_and_tokens_across_replicas() {
    // 3 scenario shapes (closed-loop chains, open-loop mix, workflow DAG)
    // × all 4 routers × 2 fleet sizes: every session completes somewhere
    // and the scripted decode-token total is conserved exactly.
    let cfg = cfg();
    let scenarios = vec![
        Scenario::by_name("paper-fig5").unwrap(),
        Scenario::by_name("mixed-fleet").unwrap(),
        workflow_scenario(4),
    ];
    for sc in &scenarios {
        let expected = scripted_tokens(&cfg, sc, 7);
        let sessions = if sc.workflow.is_some() {
            agentserve::workflow::compile(sc, cfg.model.kind, 7).scripts.len()
        } else {
            sc.total_sessions
        };
        for router in RouterPolicy::ALL {
            for replicas in [2, 3] {
                let out = run_cluster_fast(
                    &cfg,
                    Policy::AgentServe(Default::default()),
                    sc,
                    replicas,
                    router,
                    7,
                )
                .unwrap();
                let tag = format!("{}/{}/{} replicas", sc.name, router, replicas);
                assert_eq!(out.report.sessions, sessions, "{tag}");
                assert_eq!(out.report.completed_sessions, sessions, "{tag}");
                assert_eq!(out.report.total_tokens, expected, "{tag}");
                // Per-replica counts add up and every session was placed.
                let sum: u64 = out.per_replica.iter().map(|o| o.report.total_tokens).sum();
                assert_eq!(sum, expected, "{tag}");
                assert!(out.placements.iter().all(|&r| r < replicas), "{tag}");
                // Reruns are byte-identical (fleet determinism).
                let again = run_cluster_fast(
                    &cfg,
                    Policy::AgentServe(Default::default()),
                    sc,
                    replicas,
                    router,
                    7,
                )
                .unwrap();
                assert_eq!(
                    out.report.to_value().to_string(),
                    again.report.to_value().to_string(),
                    "{tag}"
                );
            }
        }
    }
}

#[test]
fn workflow_joins_resolve_across_replicas() {
    // Round-robin scatters a task's supervisor and workers across
    // replicas, so every join barrier resolves fleet-wide (workers on
    // other GPUs wake the parked supervisor). All tasks must complete and
    // report fleet-level makespans.
    let cfg = cfg();
    let sc = workflow_scenario(3);
    let out = run_cluster_fast(
        &cfg,
        Policy::AgentServe(Default::default()),
        &sc,
        3,
        RouterPolicy::RoundRobin,
        7,
    )
    .unwrap();
    let wf = out.report.workflow.as_ref().expect("workflow scenario reports tasks");
    assert_eq!(wf.tasks, 3);
    assert_eq!(wf.completed_tasks, 3);
    assert_eq!(wf.makespan.n, 3);
    assert!(wf.makespan.p50 > 0.0);
    assert!(wf.stretch > 0.0);
    // Round-robin provably split at least one task across replicas
    // (5 sessions per task, 3 replicas).
    let k = 5; // supervisor + 4 workers
    let split = out
        .placements
        .chunks(k)
        .any(|task| task.iter().any(|&r| r != task[0]));
    assert!(split, "round-robin must scatter some task: {:?}", out.placements);
}

#[test]
fn session_affinity_keeps_units_on_their_home_replica() {
    let cfg = cfg();
    // Closed-loop agents: every chained session (and therefore every one
    // of its resume steps — sessions are atomic on a replica) returns to
    // its agent's home replica.
    let sc = Scenario::by_name("paper-fig5").unwrap();
    let out = run_cluster_fast(
        &cfg,
        Policy::AgentServe(Default::default()),
        &sc,
        3,
        RouterPolicy::SessionAffinity,
        7,
    )
    .unwrap();
    let agents = sc.n_agents;
    for (g, &r) in out.placements.iter().enumerate() {
        let home = out.placements[g % agents];
        assert_eq!(r, home, "session {g} left agent {}'s home replica", g % agents);
    }
    assert_eq!(out.report.affinity_rate(), 1.0);
    assert_eq!(
        out.report.affinity_opportunities as usize,
        sc.total_sessions - agents.min(sc.total_sessions),
        "every chained session was an affinity opportunity"
    );
    // Workflow tasks: all sessions of one task colocate.
    let wf = workflow_scenario(4);
    let out = run_cluster_fast(
        &cfg,
        Policy::AgentServe(Default::default()),
        &wf,
        3,
        RouterPolicy::SessionAffinity,
        7,
    )
    .unwrap();
    for task in out.placements.chunks(5) {
        assert!(task.iter().all(|&r| r == task[0]), "task split: {:?}", out.placements);
    }
    assert_eq!(out.report.affinity_rate(), 1.0);
    // Round-robin on the same workload scatters (affinity rate < 1).
    let rr = run_cluster_fast(
        &cfg,
        Policy::AgentServe(Default::default()),
        &wf,
        3,
        RouterPolicy::RoundRobin,
        7,
    )
    .unwrap();
    assert!(rr.report.affinity_rate() < 1.0, "rate {}", rr.report.affinity_rate());
}

#[test]
fn fleet_p99_ttft_is_nonincreasing_in_replica_count() {
    // Fixed overloaded workload (coupled seeds: every fleet size replays
    // identical scenario bytes); adding replicas strictly relieves
    // queueing, so the fleet p99 TTFT must not rise. A small slack absorbs
    // floating-point percentile wiggle between near-identical schedules.
    let cfg = cfg();
    let sc = common::open_loop("overload", 2.0, 120);
    let mut prev = f64::INFINITY;
    for replicas in [1, 2, 4] {
        let out = run_cluster_fast(
            &cfg,
            Policy::AgentServe(Default::default()),
            &sc,
            replicas,
            RouterPolicy::LeastOutstanding,
            13,
        )
        .unwrap();
        let p99 = out.report.ttft.p99;
        assert!(
            p99 <= prev * 1.02,
            "p99 TTFT rose with fleet size: {p99} at {replicas} replicas (prev {prev})"
        );
        assert_eq!(out.report.completed_sessions, 120);
        prev = p99;
    }
}

#[test]
fn cache_aware_routing_beats_round_robin_on_shared_prefixes() {
    // The acceptance criterion: on the shared-prefix fleet scenario (radix
    // sharing on, 4 prompt templates), cache-aware routing shards
    // templates onto warm replicas while round-robin re-misses every
    // (template, replica) pair — strictly more radix hits fleet-wide.
    let cfg = cfg();
    let sc = Scenario::by_name("shared-prefix-fleet").unwrap();
    let run = |router| {
        run_cluster_fast(&cfg, Policy::AgentServe(Default::default()), &sc, 4, router, 7)
            .unwrap()
    };
    let aware = run(RouterPolicy::CacheAware);
    let rr = run(RouterPolicy::RoundRobin);
    assert_eq!(aware.report.completed_sessions, sc.total_sessions);
    assert_eq!(rr.report.completed_sessions, sc.total_sessions);
    assert!(
        aware.report.radix_hit_rate() > rr.report.radix_hit_rate(),
        "cache-aware {} must beat round-robin {}",
        aware.report.radix_hit_rate(),
        rr.report.radix_hit_rate()
    );
    assert!(
        aware.report.radix_hit_rate() > 0.5,
        "template sharding should keep most prompt tokens cached ({})",
        aware.report.radix_hit_rate()
    );
}

#[test]
fn replica_sweep_finds_a_finite_inverse_knee() {
    // A fixed rate past the single-GPU knee: one replica violates the TTFT
    // SLO, a finite larger fleet meets it — the gpus-for-slo semantics on
    // a CI-sized grid (the 2,000-agent registry sweep runs in ci/check.sh).
    let cfg = cfg();
    let spec = SweepSpec {
        name: "mini-gpus-for-slo".into(),
        description: "inverse knee on a small overloaded fleet".into(),
        base: common::open_loop("mini-overload", 1.5, 100),
        axis: SweepAxis::Replicas {
            counts: vec![1, 2, 4, 8],
            router: RouterPolicy::LeastOutstanding,
        },
    };
    spec.validate().unwrap();
    let report = agentserve::workload::run_sweep(
        &cfg,
        &spec,
        &[Policy::AgentServe(Default::default())],
        7,
    )
    .unwrap();
    assert_eq!(report.axis, "replicas");
    assert_eq!(report.points.len(), 4);
    // Identical workload bytes at every point (the axis varies the fleet).
    for pt in &report.points {
        assert_eq!(pt.sessions, 100);
    }
    let (_, knee) = &report.knees[0];
    let knee = knee.expect("a finite fleet meets the SLO within the grid");
    assert!(knee > 1.0, "one GPU cannot hold 3x its knee rate (knee {knee})");
    // The fleet columns ride the report: replicas echo the axis, and the
    // single-GPU point carries a zero CoV only when trivially balanced.
    for (pt, &count) in report.points.iter().zip(&[1usize, 2, 4, 8]) {
        assert_eq!(pt.per_policy[0].replicas, count);
        assert!(pt.per_policy[0].load_cov >= 0.0);
    }
    // JSON/CSV carry the fleet columns.
    let json = report.to_value().to_string();
    assert!(json.contains("\"replicas\""));
    assert!(json.contains("\"load_cov\""));
    let csv = report.to_csv();
    assert!(csv.lines().next().unwrap().ends_with("replicas,load_cov,replica_us"));
}

#[test]
fn fleet_outcome_surfaces_are_consistent() {
    let cfg = cfg();
    let sc = Scenario::by_name("mixed-fleet").unwrap();
    let out: FleetOutcome = run_cluster(
        &cfg,
        Policy::Vllm,
        &sc,
        2,
        RouterPolicy::LeastOutstanding,
        7,
    )
    .unwrap();
    assert_eq!(out.replicas, 2);
    assert_eq!(out.per_replica.len(), 2);
    assert_eq!(out.placements.len(), sc.total_sessions);
    assert_eq!(out.report.per_replica_tokens.len(), 2);
    assert!(out.report.load_cov >= 0.0);
    assert_eq!(
        out.report.ttft.n,
        out.per_replica.iter().map(|o| o.report.ttft.n).sum::<u64>(),
        "fleet TTFT samples cover every replica request"
    );
    let min_replica_wall =
        out.per_replica[0].report.wall_ms.min(out.per_replica[1].report.wall_ms);
    assert!(out.report.wall_ms >= min_replica_wall);
    // JSON form is deterministic and complete.
    let v = out.report.to_value().to_string();
    assert!(v.contains("\"router\":\"least-outstanding\""));
}
