//! Workflow DAG engine regression suite (tier-1): byte-determinism, the
//! degenerate single-agent equality contract (workflow path == legacy
//! session-script path, byte-for-byte), join-barrier token conservation
//! under every paper policy, dependency-driven arrival ordering, radix
//! prefix sharing across fan-out, and the fan-out sweep axis.

use agentserve::config::KvConfig;
use agentserve::engine::{run_scenario, Policy};
use agentserve::workflow::compile;
use agentserve::workload::{run_sweep, SweepAxis, SweepSpec};

mod common;
use common::{cfg, wf_scenario};

#[test]
fn workflow_runs_are_byte_deterministic() {
    let cfg = cfg();
    let sc = wf_scenario("supervisor-worker", 4, 0.5);
    sc.validate().unwrap();
    let policy = Policy::AgentServe(Default::default());
    let a = run_scenario(&cfg, policy, &sc, 7);
    let b = run_scenario(&cfg, policy, &sc, 7);
    assert_eq!(
        a.report.to_value().to_string(),
        b.report.to_value().to_string(),
        "same (scenario, seed) must serialize byte-identically"
    );
    let (awf, bwf) = (a.workflow.unwrap(), b.workflow.unwrap());
    assert_eq!(awf.to_value().to_string(), bwf.to_value().to_string());
    assert_eq!(a.arrivals_us, b.arrivals_us);
    // A different seed must actually change the workload.
    let c = run_scenario(&cfg, policy, &sc, 8);
    assert_ne!(a.report.to_value().to_string(), c.report.to_value().to_string());
}

#[test]
fn degenerate_single_react_matches_legacy_byte_identically() {
    // The single-node workflow must reproduce the legacy session-script
    // path exactly: same scripts, same arrivals, same simulated bytes.
    let cfg = cfg();
    let tasks = 8;
    let wf = wf_scenario("single-react", tasks, 1.0);
    let legacy = common::open_loop("wf-single-react", 1.0, tasks);
    for policy in Policy::paper_lineup() {
        let a = run_scenario(&cfg, policy, &wf, 7);
        let b = run_scenario(&cfg, policy, &legacy, 7);
        assert_eq!(
            a.report.to_value().to_string(),
            b.report.to_value().to_string(),
            "{}: degenerate workflow must match the legacy path byte-for-byte",
            policy.name()
        );
        assert_eq!(a.slo.attained, b.slo.attained, "{}", policy.name());
        assert_eq!(a.arrivals_us, b.arrivals_us, "{}", policy.name());
        assert_eq!(a.eta_cold, b.eta_cold, "{}", policy.name());
        // Only the workflow run carries task metrics; one task per session.
        let wf_report = a.workflow.expect("workflow path reports tasks");
        assert!(b.workflow.is_none(), "legacy path reports no task metrics");
        assert_eq!(wf_report.tasks, tasks);
        assert_eq!(wf_report.completed_tasks, tasks);
    }
}

#[test]
fn join_barriers_conserve_every_fanout_token() {
    // Every scripted decode token of every fan-out branch is emitted
    // exactly once, under every policy, for every registry workflow shape.
    let cfg = cfg();
    for spec_name in ["supervisor-worker", "debate", "pipeline-chain"] {
        let sc = wf_scenario(spec_name, 3, 1.0);
        sc.validate().unwrap();
        let compiled = compile(&sc, cfg.model.kind, 7);
        let expected: u64 = compiled.scripts.iter().map(|s| s.total_decode_tokens()).sum();
        for policy in Policy::paper_lineup() {
            let out = run_scenario(&cfg, policy, &sc, 7);
            assert_eq!(
                out.report.completed_sessions,
                compiled.scripts.len(),
                "{spec_name}/{}: every session completes",
                policy.name()
            );
            assert_eq!(
                out.report.total_tokens,
                expected,
                "{spec_name}/{}: decode tokens conserved across the DAG",
                policy.name()
            );
            let wf = out.workflow.expect("workflow metrics present");
            assert_eq!(wf.tasks, 3, "{spec_name}/{}", policy.name());
            assert_eq!(wf.completed_tasks, 3, "{spec_name}/{}", policy.name());
            assert_eq!(wf.makespan.n, 3, "{spec_name}/{}", policy.name());
            assert_eq!(wf.critical_path.n, 3, "{spec_name}/{}", policy.name());
            assert!(wf.makespan.p99 > 0.0, "{spec_name}/{}", policy.name());
            assert!(wf.critical_path.p50 > 0.0, "{spec_name}/{}", policy.name());
        }
    }
}

#[test]
fn dependent_sessions_arrive_only_after_their_join_resolves() {
    // Supervisor/worker: workers are released by the supervisor's first
    // burst completing plus the folded 120 ms dispatch-tool delay — the
    // dependency-driven arrival source, observable in realized arrivals.
    let cfg = cfg();
    let tasks = 3;
    let sc = wf_scenario("supervisor-worker", tasks, 1.0);
    for policy in [Policy::Vllm, Policy::AgentServe(Default::default())] {
        let out = run_scenario(&cfg, policy, &sc, 7);
        for t in 0..tasks {
            let supervisor = 5 * t;
            for w in 1..5 {
                assert!(
                    out.arrivals_us[supervisor + w] >= out.arrivals_us[supervisor] + 120_000,
                    "{}: worker {} of task {} arrived at {} before its join \
                     (supervisor cold at {})",
                    policy.name(),
                    w,
                    t,
                    out.arrivals_us[supervisor + w],
                    out.arrivals_us[supervisor]
                );
            }
        }
    }
}

#[test]
fn bounded_kv_pool_cannot_stall_parked_joins() {
    // Parked supervisors hold resident contexts while their young workers
    // wait for admission — the age-ordered preemption rule alone would
    // leave that circular wait unbreakable (old sessions are normally
    // untouchable). Parked sessions are preemption-eligible regardless of
    // age, so even the minimum legal pool (8,192 tokens, sharing off to
    // maximize pressure) must drain completely with tokens conserved.
    let mut cfg = cfg();
    cfg.kv = KvConfig { num_blocks: 512, block_size: 16, prefix_sharing: false };
    let sc = wf_scenario("supervisor-worker", 6, 4.0);
    let compiled = compile(&sc, cfg.model.kind, 7);
    let expected: u64 = compiled.scripts.iter().map(|s| s.total_decode_tokens()).sum();
    for policy in Policy::paper_lineup() {
        let out = run_scenario(&cfg, policy, &sc, 7);
        assert_eq!(
            out.report.completed_sessions,
            compiled.scripts.len(),
            "{}: every session must finish under pressure (no parked-join stall)",
            policy.name()
        );
        assert_eq!(out.report.total_tokens, expected, "{}", policy.name());
        let wf = out.workflow.expect("workflow metrics");
        assert_eq!(wf.completed_tasks, 6, "{}", policy.name());
    }
}

#[test]
fn fanout_prompts_share_the_radix_cache() {
    // With prefix sharing on a generous pool, workflow templates (shared
    // across tasks) and worker agent templates both produce radix hits —
    // the realistic shared-prefix fan-out shape the KV path is built for.
    let mut cfg = cfg();
    cfg.kv = KvConfig { num_blocks: 1 << 20, block_size: 16, prefix_sharing: true };
    let sc = wf_scenario("supervisor-worker", 4, 1.0);
    let out = run_scenario(&cfg, Policy::AgentServe(Default::default()), &sc, 7);
    let kv = out.kv.expect("sharing runs the paged path");
    assert!(
        kv.radix_hit_tokens > 0,
        "replicated workers and repeated supervisor prompts must share prefixes"
    );
    assert_eq!(out.workflow.unwrap().completed_tasks, 4);
}

#[test]
fn fanout_axis_scales_task_load_under_all_policies() {
    // An ascending fan-out grid strictly increases the work behind every
    // join; p99 makespan must follow, for each of the four paper policies,
    // and the sweep must stay byte-deterministic.
    let cfg = cfg();
    let spec = SweepSpec {
        name: "fan-test".into(),
        description: String::new(),
        base: wf_scenario("supervisor-worker", 4, 0.4),
        axis: SweepAxis::FanOut(vec![2, 8]),
    };
    spec.validate().unwrap();
    let policies = Policy::paper_lineup();
    let report = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    let again = run_sweep(&cfg, &spec, &policies, 7).unwrap();
    assert_eq!(report.to_value().to_string(), again.to_value().to_string());
    assert_eq!(report.axis, "fan-out");
    assert_eq!(report.points.len(), 2);
    assert_eq!(report.knees.len(), policies.len());
    for (pi, policy) in policies.iter().enumerate() {
        let narrow = &report.points[0].per_policy[pi];
        let wide = &report.points[1].per_policy[pi];
        assert!(narrow.makespan_p99_ms > 0.0, "{}", policy.name());
        assert!(
            wide.makespan_p99_ms > narrow.makespan_p99_ms,
            "{}: quadrupling the fan-out must raise p99 makespan ({} vs {})",
            policy.name(),
            wide.makespan_p99_ms,
            narrow.makespan_p99_ms
        );
        assert!(
            (0.0..=1.0).contains(&narrow.task_slo_rate),
            "{}: task-SLO rate is a fraction",
            policy.name()
        );
    }
    // The CSV stays in lock-step with the JSON and carries the task columns.
    let csv = report.to_csv();
    assert!(csv.lines().next().unwrap().contains("makespan_p99_ms,task_slo_rate"));
    assert_eq!(csv.lines().count(), 1 + 2 * policies.len());
}
